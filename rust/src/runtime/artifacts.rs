//! Artifact manifest: typed view over `artifacts/manifest.json` plus raw
//! binary readers for parameter/dataset blobs.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context as _, Result};

use crate::splits::{App, SplitDecision};
use crate::util::json::{self, Value};

/// One exported HLO fragment.
#[derive(Clone, Debug)]
pub struct FragmentArtifact {
    pub name: String,
    pub hlo: String,
    pub in_dim: usize,
    pub out_dim: usize,
    pub param_bytes: usize,
}

/// Per-app artifact bundle.
#[derive(Clone, Debug)]
pub struct AppArtifacts {
    pub input_dim: usize,
    pub classes: usize,
    pub layer: Vec<FragmentArtifact>,
    pub semantic: Vec<FragmentArtifact>,
    pub full: FragmentArtifact,
    pub compressed: FragmentArtifact,
    /// Held-out accuracies measured at build time.
    pub accuracy_layer: f64,
    pub accuracy_semantic: f64,
    pub accuracy_compressed: f64,
    pub data_x: String,
    pub data_y: String,
    pub data_rows: usize,
}

impl AppArtifacts {
    pub fn accuracy(&self, d: SplitDecision) -> f64 {
        match d {
            SplitDecision::Layer | SplitDecision::Full => self.accuracy_layer,
            SplitDecision::Semantic => self.accuracy_semantic,
            SplitDecision::Compressed => self.accuracy_compressed,
        }
    }

    pub fn fragments(&self, d: SplitDecision) -> Vec<&FragmentArtifact> {
        match d {
            SplitDecision::Layer => self.layer.iter().collect(),
            SplitDecision::Semantic => self.semantic.iter().collect(),
            SplitDecision::Compressed => vec![&self.compressed],
            SplitDecision::Full => vec![&self.full],
        }
    }
}

/// A surrogate variant entry.
#[derive(Clone, Debug)]
pub struct SurrogateArtifacts {
    pub workers: usize,
    pub slots: usize,
    pub feature_dim: usize,
    pub fwd: String,
    pub fwd_batch: String,
    pub fwd_batch_size: usize,
    pub grad: String,
    pub train: String,
    pub train_batch: usize,
    pub init: String,
    pub param_shapes: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub eval_batch: usize,
    pub apps: HashMap<App, AppArtifacts>,
    pub surrogates: HashMap<String, SurrogateArtifacts>,
}

fn frag(v: &Value) -> Result<FragmentArtifact> {
    Ok(FragmentArtifact {
        name: v.req("name")?.as_str()?.to_string(),
        hlo: v.req("hlo")?.as_str()?.to_string(),
        in_dim: v.req("in_dim")?.as_usize()?,
        out_dim: v.req("out_dim")?.as_usize()?,
        param_bytes: v.req("param_bytes")?.as_usize()?,
    })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = json::parse(&text).context("parsing manifest.json")?;

        let mut apps = HashMap::new();
        for (name, entry) in v.req("apps")?.as_obj()? {
            let app = App::from_name(name)
                .with_context(|| format!("unknown app '{name}' in manifest"))?;
            let acc = entry.req("accuracy")?;
            apps.insert(
                app,
                AppArtifacts {
                    input_dim: entry.req("input_dim")?.as_usize()?,
                    classes: entry.req("classes")?.as_usize()?,
                    layer: entry
                        .req("layer")?
                        .as_arr()?
                        .iter()
                        .map(frag)
                        .collect::<Result<_>>()?,
                    semantic: entry
                        .req("semantic")?
                        .as_arr()?
                        .iter()
                        .map(frag)
                        .collect::<Result<_>>()?,
                    full: frag(entry.req("full")?)?,
                    compressed: frag(entry.req("compressed")?)?,
                    accuracy_layer: acc.req("layer")?.as_f64()?,
                    accuracy_semantic: acc.req("semantic")?.as_f64()?,
                    accuracy_compressed: acc.req("compressed")?.as_f64()?,
                    data_x: entry.req("data_x")?.as_str()?.to_string(),
                    data_y: entry.req("data_y")?.as_str()?.to_string(),
                    data_rows: entry.req("data_rows")?.as_usize()?,
                },
            );
        }

        let mut surrogates = HashMap::new();
        for (name, entry) in v.req("surrogates")?.as_obj()? {
            surrogates.insert(
                name.clone(),
                SurrogateArtifacts {
                    workers: entry.req("workers")?.as_usize()?,
                    slots: entry.req("slots")?.as_usize()?,
                    feature_dim: entry.req("feature_dim")?.as_usize()?,
                    fwd: entry.req("fwd")?.as_str()?.to_string(),
                    fwd_batch: entry.req("fwd_batch")?.as_str()?.to_string(),
                    fwd_batch_size: entry.req("fwd_batch_size")?.as_usize()?,
                    grad: entry.req("grad")?.as_str()?.to_string(),
                    train: entry.req("train")?.as_str()?.to_string(),
                    train_batch: entry.req("train_batch")?.as_usize()?,
                    init: entry.req("init")?.as_str()?.to_string(),
                    param_shapes: entry
                        .req("param_shapes")?
                        .as_arr()?
                        .iter()
                        .map(|s| {
                            s.as_arr().map(|a| {
                                a.iter().map(|d| d.as_usize().unwrap_or(0)).collect()
                            })
                        })
                        .collect::<Result<_, _>>()?,
                },
            );
        }

        Ok(Manifest {
            dir,
            eval_batch: v.req("eval_batch")?.as_usize()?,
            apps,
            surrogates,
        })
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Pick the surrogate variant matching a worker count (exact match or
    /// smallest variant that fits).
    pub fn surrogate_for(&self, workers: usize) -> Result<&SurrogateArtifacts> {
        if let Some(s) = self.surrogates.values().find(|s| s.workers == workers) {
            return Ok(s);
        }
        let mut best: Option<&SurrogateArtifacts> = None;
        for s in self.surrogates.values() {
            if s.workers >= workers {
                best = match best {
                    Some(b) if b.workers <= s.workers => Some(b),
                    _ => Some(s),
                };
            }
        }
        best.ok_or_else(|| {
            anyhow::anyhow!("no surrogate variant supports {workers} workers")
        })
    }

    /// Read a little-endian f32 blob.
    pub fn read_f32(&self, file: &str) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.path(file))?;
        if bytes.len() % 4 != 0 {
            bail!("{file}: size {} not a multiple of 4", bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Read a little-endian i32 blob.
    pub fn read_i32(&self, file: &str) -> Result<Vec<i32>> {
        let bytes = std::fs::read(self.path(file))?;
        if bytes.len() % 4 != 0 {
            bail!("{file}: size {} not a multiple of 4", bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn load_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.apps.len(), 3);
        let mnist = &m.apps[&App::Mnist];
        assert_eq!(mnist.input_dim, 784);
        assert_eq!(mnist.layer.len(), 3);
        assert_eq!(mnist.semantic.len(), 2);
        assert!(mnist.accuracy_layer > 0.9);
        // chain dims compose
        assert_eq!(mnist.layer[0].out_dim, mnist.layer[1].in_dim);
        let cifar = &m.apps[&App::Cifar100];
        assert_eq!(cifar.semantic.len(), 4);
        assert_eq!(
            cifar.semantic.iter().map(|f| f.out_dim).sum::<usize>(),
            100
        );
    }

    #[test]
    fn accuracy_ladder_in_manifest() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        for app in m.apps.values() {
            assert!(app.accuracy_layer >= app.accuracy_semantic - 1e-9);
            assert!(app.accuracy_layer > app.accuracy_compressed);
        }
    }

    #[test]
    fn surrogate_selection() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.surrogate_for(50).unwrap().workers, 50);
        assert_eq!(m.surrogate_for(10).unwrap().workers, 10);
        assert_eq!(m.surrogate_for(8).unwrap().workers, 10);
        assert!(m.surrogate_for(500).is_err());
    }

    #[test]
    fn binary_blobs_parse() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        let app = &m.apps[&App::Mnist];
        let x = m.read_f32(&app.data_x).unwrap();
        let y = m.read_i32(&app.data_y).unwrap();
        assert_eq!(x.len(), app.data_rows * app.input_dim);
        assert_eq!(y.len(), app.data_rows);
        assert!(y.iter().all(|&v| v >= 0 && (v as usize) < app.classes));
        assert!(x.iter().all(|v| v.is_finite()));
    }
}
