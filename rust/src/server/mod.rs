//! Serving front-end: a thread-pool TCP server that exposes the SplitPlace
//! broker as a JSON-lines inference service (offline substitute for the
//! paper's Flask/HTTP COSCO front-end; no tokio in the offline crate set).
//!
//! Protocol (one JSON object per line):
//!   request:  {"app": "mnist", "batch": 32000, "sla": 4.0}
//!   response: {"ok": true, "decision": "layer", "accuracy": 0.98,
//!              "latency_ms": 12.3, "rows": 256, "queue_ms": 0.4}
//!
//! The handler path is fully rust + PJRT: split decision via the MAB (UCB),
//! real fragment execution via the runtime, no Python anywhere.

use std::io::{BufRead, BufReader, Write as _};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::config::MabConfig;
use crate::mab::{MabPolicy, Mode};
use crate::runtime::{InferenceEngine, Runtime};
use crate::splits::App;
use crate::util::json::{self, Value};
use crate::workload::Task;

/// Shared server state. The PJRT client is NOT thread-safe (Rc inside the
/// xla crate), so each handler thread owns a full Runtime — exactly like
/// the paper's edge workers, each of which runs its own container engine.
struct Shared {
    artifacts_dir: String,
    mab: Mutex<MabPolicy>,
    requests: AtomicU64,
    stop: AtomicBool,
    /// Worker threads whose runtime loaded successfully.
    ready_workers: AtomicUsize,
    /// Worker threads that died before serving (runtime load failure).
    dead_workers: AtomicUsize,
}

/// Handle for a running server.
pub struct Server {
    pub addr: std::net::SocketAddr,
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and serve on `addr` (e.g. "127.0.0.1:0") with `workers`
    /// handler threads, emulating the paper's worker fleet: each handler
    /// thread owns a PJRT-executing "edge worker".
    pub fn start(artifacts_dir: &str, addr: &str, workers: usize) -> Result<Server> {
        let listener = TcpListener::bind(addr).context("binding server socket")?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            artifacts_dir: artifacts_dir.to_string(),
            mab: Mutex::new(MabPolicy::new(MabConfig::default(), Mode::Test)),
            requests: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            ready_workers: AtomicUsize::new(0),
            dead_workers: AtomicUsize::new(0),
        });

        // bounded handoff queue: accept thread -> worker pool
        let queue: Arc<(Mutex<Vec<TcpStream>>, std::sync::Condvar)> =
            Arc::new((Mutex::new(Vec::new()), std::sync::Condvar::new()));

        let mut threads = Vec::new();
        for _ in 0..workers.max(1) {
            let q = queue.clone();
            let sh = shared.clone();
            threads.push(std::thread::spawn(move || {
                // per-thread PJRT runtime (see Shared docs)
                let runtime = match Runtime::load(&sh.artifacts_dir) {
                    Ok(rt) => {
                        sh.ready_workers.fetch_add(1, Ordering::SeqCst);
                        rt
                    }
                    Err(e) => {
                        crate::log_error!(
                            "server worker thread died: failed to load runtime from {}: {e:#}",
                            sh.artifacts_dir
                        );
                        sh.dead_workers.fetch_add(1, Ordering::SeqCst);
                        return;
                    }
                };
                loop {
                let stream = {
                    let (lock, cv) = &*q;
                    let mut guard = lock.lock().unwrap();
                    while guard.is_empty() {
                        if sh.stop.load(Ordering::Relaxed) {
                            return;
                        }
                        let (g, _timeout) = cv
                            .wait_timeout(guard, std::time::Duration::from_millis(50))
                            .unwrap();
                        guard = g;
                    }
                    guard.pop()
                };
                if let Some(stream) = stream {
                    let _ = handle_conn(stream, &sh, &runtime);
                }
                }
            }));
        }

        // Surface a server-level startup failure when EVERY worker thread
        // dies loading its runtime — a server with no workers would accept
        // connections and never answer them.
        let n_workers = workers.max(1);
        loop {
            if shared.ready_workers.load(Ordering::SeqCst) > 0 {
                break;
            }
            if shared.dead_workers.load(Ordering::SeqCst) == n_workers {
                anyhow::bail!(
                    "server startup failed: all {n_workers} worker threads failed to load \
                     the runtime from {artifacts_dir} (see log for per-worker errors)"
                );
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }

        let q2 = queue.clone();
        let sh2 = shared.clone();
        let accept_thread = std::thread::spawn(move || loop {
            if sh2.stop.load(Ordering::Relaxed) {
                return;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let (lock, cv) = &*q2;
                    lock.lock().unwrap().push(stream);
                    cv.notify_one();
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => return,
            }
        });

        Ok(Server { addr: local, shared, threads, accept_thread: Some(accept_thread) })
    }

    pub fn requests_served(&self) -> u64 {
        self.shared.requests.load(Ordering::Relaxed)
    }

    /// Worker threads that loaded their runtime and are serving.
    pub fn live_workers(&self) -> usize {
        self.shared.ready_workers.load(Ordering::SeqCst)
    }

    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, sh: &Shared, runtime: &Runtime) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Bounded reads so shutdown() can join workers while clients hold
    // their connections open.
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(200)))
        .ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let engine = InferenceEngine::new(runtime)?;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if sh.stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let reply = match serve_one(&line, sh, &engine) {
            Ok(mut v) => {
                if let Value::Obj(kv) = &mut v {
                    kv.push((
                        "latency_ms".into(),
                        Value::Num(t0.elapsed().as_secs_f64() * 1000.0),
                    ));
                }
                v
            }
            Err(e) => Value::obj(vec![
                ("ok", Value::Bool(false)),
                ("error", Value::Str(format!("{e:#}"))),
            ]),
        };
        sh.requests.fetch_add(1, Ordering::Relaxed);
        out.write_all(reply.to_string().as_bytes())?;
        out.write_all(b"\n")?;
    }
}

fn serve_one(line: &str, sh: &Shared, engine: &InferenceEngine) -> Result<Value> {
    let req = json::parse(line.trim()).context("bad request json")?;
    let app = App::from_name(req.req("app")?.as_str()?)
        .ok_or_else(|| anyhow::anyhow!("unknown app"))?;
    let batch = req.get("batch").and_then(|v| v.as_f64().ok()).unwrap_or(16_000.0) as u64;
    let sla = req.get("sla").and_then(|v| v.as_f64().ok()).unwrap_or(5.0);

    // MAB split decision (UCB), then real PJRT execution of the plan.
    let task = Task { id: 0, app, batch, sla, arrival_s: 0.0, decision: None };
    let decision = sh.mab.lock().unwrap().decide(&task);
    let result = engine.run(app, decision)?;

    Ok(Value::obj(vec![
        ("ok", Value::Bool(true)),
        ("app", Value::Str(app.name().into())),
        ("decision", Value::Str(decision.name().into())),
        ("accuracy", Value::Num(result.accuracy)),
        ("rows", Value::Num(result.rows as f64)),
        ("compute_ms", Value::Num(result.compute_s * 1000.0)),
    ]))
}

/// Minimal client for examples/tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn request(&mut self, app: &str, batch: u64, sla: f64) -> Result<Value> {
        let req = Value::obj(vec![
            ("app", Value::Str(app.into())),
            ("batch", Value::Num(batch as f64)),
            ("sla", Value::Num(sla)),
        ]);
        self.stream.write_all(req.to_string().as_bytes())?;
        self.stream.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(json::parse(line.trim())?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::try_runtime;

    #[test]
    fn serve_and_query() {
        if try_runtime().is_none() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let dir = crate::coordinator::runner::artifacts_dir();
        let server = Server::start(&dir, "127.0.0.1:0", 2).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        for (app, sla) in [("mnist", 9.0), ("cifar100", 1.0), ("fashionmnist", 5.0)] {
            let r = client.request(app, 20_000, sla).unwrap();
            assert_eq!(r.get("ok").unwrap().as_bool().unwrap(), true, "{r}");
            let acc = r.get("accuracy").unwrap().as_f64().unwrap();
            assert!(acc > 0.3, "{app}: accuracy {acc}");
            assert!(r.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);
            let d = r.get("decision").unwrap().as_str().unwrap().to_string();
            assert!(d == "layer" || d == "semantic");
        }
        assert_eq!(server.requests_served(), 3);
        server.shutdown();
    }

    #[test]
    fn startup_fails_loudly_when_all_workers_die() {
        // no artifacts at this path: every worker thread dies loading its
        // runtime, and start() must surface that instead of hanging
        let err = Server::start("/nonexistent/splitplace_artifacts", "127.0.0.1:0", 2)
            .err()
            .expect("start must fail with no live workers");
        let msg = format!("{err:#}");
        assert!(msg.contains("all 2 worker threads"), "got: {msg}");
    }

    #[test]
    fn bad_request_reports_error() {
        if try_runtime().is_none() {
            return;
        }
        let dir = crate::coordinator::runner::artifacts_dir();
        let server = Server::start(&dir, "127.0.0.1:0", 1).unwrap();
        let mut client = Client::connect(server.addr).unwrap();
        let r = client.request("not-an-app", 1, 1.0).unwrap();
        assert_eq!(r.get("ok").unwrap().as_bool().unwrap(), false);
        server.shutdown();
    }
}
