//! The broker loop — paper Algorithm 1, generalized over all evaluated
//! policies.
//!
//! Per interval: admit Poisson arrivals, take split decisions, place
//! containers, simulate the interval, feed the leaving tasks E_t back into
//! the policy stack, compute `O^P = O^MAB − α·AEC − β·ART` (eq. 10), and
//! fine-tune the surrogate online (line 14).
//!
//! The broker is policy-agnostic: it holds exactly one
//! [`DecisionStack`] (a [`crate::coordinator::Splitter`] + a
//! [`crate::placement::Placer`]) built by the [`PolicyKind::stack`]
//! factory — no per-policy fields, no placer enum. Every policy-specific
//! behavior lives behind the two traits.

use std::time::Instant;

use crate::cluster::build_fleet;
use crate::config::{AccuracyMode, ExperimentConfig};
use crate::mab::{MabPolicy, Mode};
use crate::metrics::Metrics;
use crate::placement::{BestFitPlacer, Placer, PlacementInput, SlotInfo};
use crate::runtime::Runtime;
use crate::sim::{Engine, EngineCmd, WorkerSnapshot, RAM_OVERCOMMIT};
use crate::splits::SplitDecision;
use crate::traffic::{self, AdmissionVerdict, Autoscaler, TrafficModel};
use crate::util::rng::{mix, Rng};
use crate::workload::generator::Generator;
use crate::workload::replay::{self, Replay};
use crate::workload::trace::{TraceBuffer, TraceSample};

use super::decision::{DecisionStack, SplitCtx};
use super::oracle::AccuracyOracle;

/// Cap used to normalize ART into [0,1] for eq. 10.
const ART_NORM: f64 = 12.0;

pub struct Broker<'rt> {
    pub cfg: ExperimentConfig,
    pub engine: Engine,
    generator: Generator,
    stack: DecisionStack<'rt>,
    pub metrics: Metrics,
    oracle: AccuracyOracle<'rt>,
    trace: TraceBuffer,
    rng: Rng,
    last_snapshots: Vec<WorkerSnapshot>,
    /// Total tasks admitted (decisions taken) over the broker's lifetime,
    /// including pre-training intervals. Chaos oracles audit against this.
    pub admitted: u64,
    /// Flash-crowd injection: when set, overrides the configured Poisson λ.
    lambda_override: Option<f64>,
    /// Traffic plane (`crate::traffic`): the arrival-process model shaping
    /// per-interval λ (flat by default — byte-identical to the raw
    /// generator stream), an optional recorded trace that replaces
    /// generation entirely, and the optional autoscaler.
    traffic_model: Box<dyn TrafficModel>,
    trace_replay: Option<Replay>,
    autoscaler: Option<Autoscaler>,
    /// Previous interval's waiting-queue depth — the backlog signal both
    /// admission shedding and autoscaling react to.
    last_queued: usize,
    /// Traffic-plane counters, surfaced as `CellSummary` metrics.
    /// `offered` counts every arrival before admission control;
    /// `offered == admitted_here + shed_queue + shed_deadline`.
    pub offered: u64,
    pub shed_queue: u64,
    pub shed_deadline: u64,
    pub scale_up: u64,
    pub scale_down: u64,
    /// `PlacementInput` assembly scratch (slots, per-worker capacity,
    /// resident RAM): taken before each `place` call and reclaimed from
    /// the input afterwards, so steady-state intervals assemble the
    /// decision input without heap churn.
    place_slots: Vec<SlotInfo>,
    place_caps: Vec<f64>,
    place_resident: Vec<f64>,
}

impl<'rt> Broker<'rt> {
    /// Build a broker. `runtime` is required for the surrogate-based
    /// policies (M+D, M+G, R+D, L+G, S+G); Gillis/MC run without it.
    pub fn new(
        cfg: ExperimentConfig,
        runtime: Option<&'rt Runtime>,
        mab_mode: Mode,
    ) -> anyhow::Result<Self> {
        Self::build(cfg, runtime, mab_mode, false)
    }

    /// Like [`Broker::new`], but a surrogate-based policy degrades to
    /// best-fit placement when the PJRT runtime is unavailable instead of
    /// erroring. The split decider (MAB / fixed / baseline) is unaffected.
    /// Used by the chaos harness so fault-injection runs work without
    /// built artifacts.
    pub fn new_with_fallback(
        cfg: ExperimentConfig,
        runtime: Option<&'rt Runtime>,
        mab_mode: Mode,
    ) -> anyhow::Result<Self> {
        Self::build(cfg, runtime, mab_mode, true)
    }

    fn build(
        cfg: ExperimentConfig,
        runtime: Option<&'rt Runtime>,
        mab_mode: Mode,
        fallback_placer: bool,
    ) -> anyhow::Result<Self> {
        let cluster = build_fleet(&cfg.cluster);
        let n_workers = cluster.len();
        let cost_per_hour: f64 = cluster.workers.iter().map(|w| w.spec.cost_per_hr).sum();
        let mut engine = Engine::new(cluster, cfg.sim.clone(), cfg.cluster.seed ^ 0xE);
        engine.apply(EngineCmd::SetChurn { rate: cfg.cluster.churn_rate });
        let generator = Generator::new(cfg.workload.clone());

        let stack = cfg.policy.stack(&cfg, runtime, mab_mode, fallback_placer)?;

        let oracle = match (&cfg.accuracy, runtime) {
            (AccuracyMode::Measured, Some(rt)) => AccuracyOracle::measured(rt, 77)?,
            (_, Some(rt)) => AccuracyOracle::manifest(rt, 77),
            (_, None) => AccuracyOracle::synthetic(77),
        };

        let metrics = Metrics::new(n_workers, cost_per_hour, cfg.sim.interval_seconds);
        let seed = cfg.workload.seed ^ 0xB0B;

        let traffic_model =
            cfg.traffic.shape.build(mix(cfg.workload.seed, traffic::TRAFFIC_STREAM_TAG));
        let trace_replay = match &cfg.traffic.trace {
            Some(path) => {
                let resolved = traffic::resolve_trace_path(path);
                let tasks = replay::load(&resolved)?;
                Some(Replay::new(tasks, cfg.sim.interval_seconds))
            }
            None => None,
        };
        let autoscaler = cfg.traffic.autoscale.map(Autoscaler::new);

        Ok(Broker {
            cfg,
            engine,
            generator,
            stack,
            metrics,
            oracle,
            trace: TraceBuffer::new(512),
            rng: Rng::new(seed),
            last_snapshots: vec![WorkerSnapshot::default(); n_workers],
            admitted: 0,
            lambda_override: None,
            traffic_model,
            trace_replay,
            autoscaler,
            last_queued: 0,
            offered: 0,
            shed_queue: 0,
            shed_deadline: 0,
            scale_up: 0,
            scale_down: 0,
            place_slots: Vec::new(),
            place_caps: Vec::new(),
            place_resident: Vec::new(),
        })
    }

    /// Flash-crowd injection: override the arrival rate (None restores the
    /// configured λ).
    pub fn set_lambda_override(&mut self, lambda: Option<f64>) {
        self.lambda_override = lambda;
    }

    /// The MAB policy behind the stack, when the configured policy has one
    /// (benches chart its Fig. 6 internals).
    pub fn mab(&self) -> Option<&MabPolicy> {
        self.stack.mab()
    }

    /// Split decisions recorded by the stack's own counters, if tracked
    /// (the chaos `mab-accounting` oracle audits this).
    pub fn decision_count(&self) -> Option<u64> {
        self.stack.decision_count()
    }

    fn decide(&mut self, task: &crate::workload::Task) -> SplitDecision {
        self.stack.decide(task, &mut SplitCtx { rng: &mut self.rng })
    }

    /// Assemble the interval's `PlacementInput` from the engine into the
    /// broker's scratch buffers (passed in taken-out, returned inside the
    /// input — [`Broker::reclaim_input`] hands them back). Slot order is
    /// `Engine::placeable`'s ascending-id order, unchanged.
    fn placement_input<'s>(
        engine: &Engine,
        snapshots: &'s [WorkerSnapshot],
        mut slots: Vec<SlotInfo>,
        mut caps: Vec<f64>,
        mut resident: Vec<f64>,
    ) -> PlacementInput<'s> {
        slots.clear();
        slots.extend(
            engine
                .active_ids()
                .iter()
                .copied()
                .filter(|&cid| engine.containers()[cid].is_placeable())
                .map(|cid| {
                    let c = &engine.containers()[cid];
                    SlotInfo {
                        cid,
                        prev_worker: c.worker,
                        decision: c.decision,
                        mi_remaining: c.mi_total - c.mi_done,
                        ram_mb: c.ram_mb,
                        input_mb: c.input_mb,
                        remaining_frac: c.remaining_fraction(),
                    }
                }),
        );
        caps.clear();
        caps.extend(engine.cluster.workers.iter().map(|w| w.spec.ram_mb));
        engine.resident_ram_into(&mut resident);
        PlacementInput {
            snapshots,
            slots,
            ram_capacity: caps,
            resident_ram: resident,
            overcommit: RAM_OVERCOMMIT,
        }
    }

    /// Reclaim the scratch buffers a spent `PlacementInput` owns.
    fn reclaim_input(&mut self, input: PlacementInput) {
        let PlacementInput { slots, ram_capacity, resident_ram, .. } = input;
        self.place_slots = slots;
        self.place_caps = ram_capacity;
        self.place_resident = resident_ram;
    }

    /// One scheduling interval (Algorithm 1 body). Returns the interval's
    /// O^P objective.
    pub fn step(&mut self) -> f64 {
        self.step_report().0
    }

    /// Like [`Broker::step`], but also hands back the engine's interval
    /// report so callers (the chaos harness) can audit what happened.
    pub fn step_report(&mut self) -> (f64, crate::sim::IntervalReport) {
        let t0 = Instant::now();
        // phase profiling (inert unless cfg.sim.profile_phases): the
        // broker charges its traffic and decision phases to the engine's
        // timer so one breakdown covers the whole interval. Timing reads
        // never feed back into scheduling state.
        let tok = self.engine.phases().start();

        // 0. autoscaling: react to the previous interval's backlog against
        // the live availability surface. At most one park/unpark per
        // interval, bus-routed with the Autoscale ledger origin.
        if let Some(scaler) = &mut self.autoscaler {
            if let Some(cmd) = scaler.plan(
                self.last_queued,
                self.engine.online(),
                self.engine.offline_origins(),
            ) {
                match cmd {
                    EngineCmd::WorkerJoin { .. } => self.scale_up += 1,
                    _ => self.scale_down += 1,
                }
                self.engine.apply_scaling(cmd);
            }
        }

        // 1. new tasks (replayed trace, or generated under the traffic
        // model's per-interval λ) + admission control + split decisions
        let now = self.engine.now_s;
        let tasks = match &mut self.trace_replay {
            Some(r) => r.next_interval(),
            None => {
                let base = self.lambda_override.unwrap_or(self.cfg.workload.lambda);
                let t = (now / self.cfg.sim.interval_seconds).round() as usize;
                let lambda = self.traffic_model.lambda_at(t, base);
                let mut tasks = self.generator.arrivals_with(now, lambda);
                self.traffic_model.shape_tasks(&mut tasks);
                tasks
            }
        };
        self.engine.phases_mut().stop(crate::util::phase_timer::Phase::Traffic, tok);
        let tok = self.engine.phases().start();
        let mut decisions = Vec::with_capacity(tasks.len());
        for task in tasks {
            self.offered += 1;
            // shed BEFORE the split decision: a shed task is never decided,
            // never admitted to the engine, never seen by the MAB — the
            // mab-accounting and task-conservation oracles stay exact
            if let Some(adm) = &self.cfg.traffic.admission {
                match adm.verdict(&task, self.last_queued) {
                    AdmissionVerdict::ShedQueueDepth => {
                        self.shed_queue += 1;
                        continue;
                    }
                    AdmissionVerdict::ShedDeadlineRisk => {
                        self.shed_deadline += 1;
                        continue;
                    }
                    AdmissionVerdict::Admit => {}
                }
            }
            let d = self.decide(&task);
            decisions.push(d);
            self.engine.admit(task, d);
            self.admitted += 1;
        }
        self.metrics.record_decisions(&decisions);

        // 2. placement
        let snapshots = std::mem::take(&mut self.last_snapshots);
        let input = Self::placement_input(
            &self.engine,
            &snapshots,
            std::mem::take(&mut self.place_slots),
            std::mem::take(&mut self.place_caps),
            std::mem::take(&mut self.place_resident),
        );
        let assignment = self.stack.place(&input);
        self.reclaim_input(input);
        self.last_snapshots = snapshots;
        self.engine.apply_placement(&assignment);
        self.engine.phases_mut().stop(crate::util::phase_timer::Phase::Decision, tok);
        let sched_s = t0.elapsed().as_secs_f64();

        // 3. simulate the interval
        let mut report = self.engine.step_interval();
        self.last_snapshots = report.snapshots.clone();
        self.last_queued = report.queued;

        // 4. accuracies for leaving tasks
        for t in &mut report.completed {
            t.accuracy = self.oracle.accuracy(t.app, t.decision);
        }

        // 5. learning updates: the splitter sees completions first (its
        // own objective when it defines one), then failures
        let o_mab = match self.stack.observe_interval(&report.completed) {
            Some(o) => o,
            // reward signal still defined for non-MAB policies (eq. 15 term)
            None => Self::mean_task_reward(&report.completed),
        };
        self.stack.observe_failures(&report.failed);

        // 6. eq. 10 objective + surrogate fine-tune (line 14)
        let art = crate::util::stats::mean(
            &report.completed.iter().map(|t| t.response).collect::<Vec<_>>(),
        );
        let art_norm = (art / ART_NORM).clamp(0.0, 1.0);
        let alpha = self.cfg.placement.alpha;
        let beta = self.cfg.placement.beta();
        let o_p = o_mab - alpha * report.aec - beta * art_norm;

        self.stack.observe_objective(
            o_p,
            &mut self.trace,
            self.cfg.placement.finetune_steps,
            &mut self.rng,
        );

        // 7. metrics
        self.metrics.record_interval(&report, sched_s, o_mab);
        (o_p, report)
    }

    fn mean_task_reward(completed: &[crate::sim::CompletedTask]) -> f64 {
        if completed.is_empty() {
            0.0
        } else {
            completed.iter().map(crate::mab::Bandit::task_reward).sum::<f64>()
                / completed.len() as f64
        }
    }

    /// Run the configured number of intervals.
    pub fn run(&mut self) -> &Metrics {
        for _ in 0..self.cfg.sim.intervals {
            self.step();
        }
        &self.metrics
    }

    /// Surrogate pre-training (paper: GOBI/DASO trained on an execution
    /// trace dataset before deployment): run `intervals` with best-fit
    /// placement to collect traces, then fit the surrogate, then reset
    /// metrics. No-op for heuristic-placer stacks.
    pub fn pretrain(&mut self, intervals: usize, steps: usize) -> anyhow::Result<()> {
        if !self.stack.learned_placer() {
            return Ok(());
        }
        // temporarily swap in best-fit
        for _ in 0..intervals {
            // admit + simulate a lightweight interval
            let tasks = self.generator.arrivals(self.engine.now_s);
            for task in tasks {
                let d = self.decide(&task);
                self.engine.admit(task, d);
                self.admitted += 1;
            }
            let snapshots = std::mem::take(&mut self.last_snapshots);
            let input = Self::placement_input(
                &self.engine,
                &snapshots,
                std::mem::take(&mut self.place_slots),
                std::mem::take(&mut self.place_caps),
                std::mem::take(&mut self.place_resident),
            );
            let assignment = BestFitPlacer::new().place(&input);
            self.reclaim_input(input);
            self.last_snapshots = snapshots;
            self.engine.apply_placement(&assignment);
            let mut report = self.engine.step_interval();
            for t in &mut report.completed {
                t.accuracy = self.oracle.accuracy(t.app, t.decision);
            }
            let o_mab = Self::mean_task_reward(&report.completed);
            let art = crate::util::stats::mean(
                &report.completed.iter().map(|t| t.response).collect::<Vec<_>>(),
            );
            let o_p = o_mab
                - self.cfg.placement.alpha * report.aec
                - self.cfg.placement.beta() * (art / ART_NORM).clamp(0.0, 1.0);
            // featurize the realized state for the trace
            if let Some(x) = self.stack.featurize_idle(&report.snapshots) {
                self.trace.push(TraceSample { features: x, objective: o_p as f32 });
            }
            self.last_snapshots = report.snapshots;
        }
        self.stack.pretrain_placer(&self.trace, steps, &mut self.rng)
    }

    /// Telemetry from the gradient placer (perf + Fig. 6-style debugging).
    pub fn placer_stats(&self) -> Option<(usize, f32)> {
        self.stack.placer_stats()
    }

    /// `--paranoid` wiring for the decision plane: make the placer re-run
    /// its retired full-fleet scan beside every indexed query and record
    /// any mismatch (drained by [`Broker::take_placement_divergences`]).
    pub fn set_placement_paranoid(&mut self, on: bool) {
        self.stack.set_placer_paranoid(on);
    }

    /// Drain index-vs-scan placement divergences recorded since the last
    /// call. Always empty outside paranoid mode and on a correct index.
    pub fn take_placement_divergences(&mut self) -> Vec<String> {
        self.stack.take_placer_divergences()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PolicyKind};

    /// Policies that need no artifacts can run anywhere.
    #[test]
    fn mc_policy_runs_without_runtime() {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::ModelCompression;
        cfg.sim.intervals = 10;
        let mut b = Broker::new(cfg, None, Mode::Test).unwrap();
        b.run();
        let s = b.metrics.summary("MC");
        assert!(s.tasks > 0, "tasks must complete");
        assert!(s.accuracy > 0.3 && s.accuracy < 1.0);
        assert!(s.energy_mwh > 0.0);
    }

    #[test]
    fn gillis_policy_runs_without_runtime() {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::Gillis;
        cfg.sim.intervals = 10;
        let mut b = Broker::new(cfg, None, Mode::Test).unwrap();
        b.run();
        assert!(b.metrics.summary("Gillis").tasks > 0);
    }

    #[test]
    fn gradient_policy_requires_runtime() {
        let cfg = ExperimentConfig::small();
        assert!(Broker::new(cfg, None, Mode::Test).is_err());
    }

    #[test]
    fn fallback_broker_runs_gradient_policy_without_runtime() {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::MabDaso;
        cfg.sim.intervals = 8;
        let mut b = Broker::new_with_fallback(cfg, None, Mode::Test).unwrap();
        b.run();
        assert!(b.metrics.summary("M+D/best-fit").tasks > 0);
        assert!(b.admitted > 0, "admission counter must advance");
        // the stack exposes MAB introspection and decision accounting
        assert!(b.mab().is_some());
        assert!(b.decision_count().unwrap() > 0);
    }

    #[test]
    fn lambda_override_scales_arrivals() {
        let run = |mult: Option<f64>| -> u64 {
            let mut cfg = ExperimentConfig::small();
            cfg.policy = PolicyKind::ModelCompression;
            cfg.sim.intervals = 10;
            let mut b = Broker::new(cfg, None, Mode::Test).unwrap();
            b.set_lambda_override(mult);
            for _ in 0..10 {
                b.step();
            }
            b.admitted
        };
        let base = run(None);
        let crowd = run(Some(20.0));
        assert!(crowd > 2 * base.max(1), "base={base} crowd={crowd}");
    }

    #[test]
    fn decisions_recorded_per_interval() {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::ModelCompression;
        cfg.sim.intervals = 5;
        let mut b = Broker::new(cfg, None, Mode::Test).unwrap();
        b.run();
        assert_eq!(b.metrics.layer_fraction.len(), 5);
    }

    #[test]
    fn admission_control_sheds_and_counts_exactly() {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::ModelCompression;
        cfg.sim.intervals = 10;
        cfg.workload.lambda = 8.0;
        // aggressive shedding so both verdicts fire at this horizon
        cfg.traffic.admission = Some(crate::traffic::AdmissionConfig {
            max_queue_depth: 3,
            deadline_floor: 0.8,
        });
        let mut b = Broker::new(cfg, None, Mode::Test).unwrap();
        for _ in 0..10 {
            b.step();
        }
        assert!(b.offered > 0);
        assert_eq!(
            b.offered,
            b.admitted + b.shed_queue + b.shed_deadline,
            "every offered task is admitted or shed, exactly once"
        );
        assert!(b.shed_queue + b.shed_deadline > 0, "nothing was ever shed");
        assert!(b.admitted > 0, "shedding must not starve the run");
        // shed tasks never reached the engine or the decision stack
        assert_eq!(b.engine.admitted_task_count() as u64, b.admitted);
    }

    #[test]
    fn autoscaler_parks_idle_capacity_through_the_ledger() {
        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::ModelCompression;
        cfg.sim.intervals = 12;
        cfg.workload.lambda = 0.5; // mostly idle fleet
        cfg.traffic.autoscale = Some(crate::traffic::AutoscaleConfig {
            queue_hi: 2.0,
            queue_lo: 0.5,
            min_online: 4,
        });
        let mut b = Broker::new(cfg, None, Mode::Test).unwrap();
        for _ in 0..12 {
            b.step();
        }
        assert!(b.scale_down > 0, "an idle fleet must shrink");
        let online = b.engine.online().iter().filter(|&&o| o).count();
        assert!(online >= 4, "never below min_online");
        // every scaling action is a ledger-audited Autoscale command
        let autoscale_cmds = b
            .engine
            .ledger()
            .iter()
            .filter(|r| r.origin == crate::sim::CmdOrigin::Autoscale)
            .count() as u64;
        assert_eq!(autoscale_cmds, b.scale_up + b.scale_down);
    }

    #[test]
    fn trace_replay_feeds_the_recorded_stream_verbatim() {
        let wl = crate::config::WorkloadConfig {
            lambda: 4.0,
            ..Default::default()
        };
        let tasks =
            crate::traffic::generate_trace(&wl, crate::traffic::TrafficShape::Flat, 6, 300.0);
        assert!(!tasks.is_empty());
        let path = std::env::temp_dir()
            .join(format!("splitplace_broker_trace_{}.json", std::process::id()));
        crate::workload::replay::save(&tasks, &path).unwrap();

        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::ModelCompression;
        cfg.sim.intervals = 6;
        cfg.traffic.trace = Some(path.to_string_lossy().into_owned());
        let mut b = Broker::new(cfg, None, Mode::Test).unwrap();
        for _ in 0..6 {
            b.step();
        }
        assert_eq!(b.offered as usize, tasks.len(), "trace must replay task-for-task");
        assert_eq!(b.admitted as usize, tasks.len());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_trace_file_errors_with_the_path() {
        let mut cfg = ExperimentConfig::small();
        cfg.traffic.trace = Some("/nonexistent/trace-xyz.json".into());
        cfg.policy = PolicyKind::ModelCompression;
        let err = Broker::new(cfg, None, Mode::Test).unwrap_err();
        assert!(format!("{err:#}").contains("trace-xyz"), "{err:#}");
    }

    #[test]
    fn broker_holds_no_policy_specific_state_outside_the_stack() {
        // Every PolicyKind runs through the one generic loop; the only
        // difference observable from here is the stack it was built with.
        for policy in PolicyKind::all() {
            let mut cfg = ExperimentConfig::small();
            cfg.policy = policy;
            cfg.sim.intervals = 4;
            let mut b = Broker::new_with_fallback(cfg, None, Mode::Test).unwrap();
            b.run();
            assert!(b.admitted > 0, "{policy:?} must admit tasks");
        }
    }
}
