//! Accuracy oracle: supplies the per-task inference accuracy p_i.
//!
//! `Measured` executes the real AOT fragment HLOs through PJRT (end-to-end
//! path; results cached per (app, decision) since the held-out batch is
//! fixed). `Manifest` reads the build-time accuracies from the manifest.
//! `Synthetic` supplies nominal values for artifact-free unit tests.
//! All modes add small seeded jitter so per-task accuracies vary like the
//! paper's per-batch measurements.

use std::collections::HashMap;

use crate::runtime::{InferenceEngine, Runtime};
use crate::splits::{App, SplitDecision, APPS};
use crate::util::rng::Rng;

pub struct AccuracyOracle<'rt> {
    base: HashMap<(App, SplitDecision), f64>,
    engine: Option<InferenceEngine<'rt>>,
    rng: Rng,
    jitter: f64,
}

const DECISIONS: [SplitDecision; 4] = [
    SplitDecision::Layer,
    SplitDecision::Semantic,
    SplitDecision::Compressed,
    SplitDecision::Full,
];

impl<'rt> AccuracyOracle<'rt> {
    /// Build-time accuracies from the manifest (fast sweep mode).
    pub fn manifest(rt: &'rt Runtime, seed: u64) -> Self {
        let mut base = HashMap::new();
        for (&app, a) in &rt.manifest.apps {
            for d in DECISIONS {
                base.insert((app, d), a.accuracy(d));
            }
        }
        AccuracyOracle { base, engine: None, rng: Rng::new(seed), jitter: 0.01 }
    }

    /// Really execute the fragment HLOs once per (app, decision) and use
    /// the measured accuracy (end-to-end mode).
    pub fn measured(rt: &'rt Runtime, seed: u64) -> anyhow::Result<Self> {
        let engine = InferenceEngine::new(rt)?;
        let mut base = HashMap::new();
        for app in APPS {
            for d in DECISIONS {
                let r = engine.run(app, d)?;
                base.insert((app, d), r.accuracy);
            }
        }
        Ok(AccuracyOracle { base, engine: Some(engine), rng: Rng::new(seed), jitter: 0.01 })
    }

    /// Nominal constants for artifact-free tests: the paper's Fig. 2 ladder.
    pub fn synthetic(seed: u64) -> Self {
        let mut base = HashMap::new();
        let table = [
            (App::Mnist, [0.99, 0.97, 0.93, 0.99]),
            (App::FashionMnist, [0.91, 0.87, 0.82, 0.91]),
            (App::Cifar100, [0.65, 0.58, 0.50, 0.65]),
        ];
        for (app, accs) in table {
            for (d, &a) in DECISIONS.iter().zip(accs.iter()) {
                base.insert((app, *d), a);
            }
        }
        AccuracyOracle { base, engine: None, rng: Rng::new(seed), jitter: 0.015 }
    }

    /// Accuracy for one finished task.
    pub fn accuracy(&mut self, app: App, d: SplitDecision) -> f64 {
        let base = *self.base.get(&(app, d)).unwrap_or(&0.5);
        (base + self.rng.normal() * self.jitter).clamp(0.0, 1.0)
    }

    /// Whether real PJRT inference backs this oracle.
    pub fn is_measured(&self) -> bool {
        self.engine.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_ladder() {
        let mut o = AccuracyOracle::synthetic(1);
        for app in APPS {
            let l = o.accuracy(app, SplitDecision::Layer);
            let c = o.accuracy(app, SplitDecision::Compressed);
            assert!(l > c, "{app:?}");
        }
    }

    #[test]
    fn jitter_is_bounded_and_seeded() {
        let sample = |seed| {
            let mut o = AccuracyOracle::synthetic(seed);
            (0..20)
                .map(|_| o.accuracy(App::Mnist, SplitDecision::Layer))
                .collect::<Vec<_>>()
        };
        let a = sample(3);
        let b = sample(3);
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| (0.9..=1.0).contains(&x)));
        let spread = a.iter().cloned().fold(0.0f64, f64::max)
            - a.iter().cloned().fold(1.0f64, f64::min);
        assert!(spread > 0.0, "jitter must vary per task");
    }
}
