//! The decision plane: pluggable split/place policy stacks.
//!
//! The paper's claims are comparative — seven policy stacks race on
//! reward/ART/SLA (Table 4) — so the broker must treat "which policy" as
//! data, not structure. This module defines the two decision traits and
//! their composition:
//!
//! * [`Splitter`] — per-task split decision (MAB / fixed / random /
//!   Gillis RL / model compression) plus the interval feedback hooks
//!   (`observe_interval`, `observe_failures`);
//! * [`crate::placement::Placer`] — container placement plus the
//!   surrogate learning hooks (gradient DASO/GOBI or heuristics);
//! * [`DecisionStack`] — one splitter + one placer, built by the
//!   [`PolicyKind::stack`] factory. The broker holds exactly one stack
//!   and nothing policy-specific.
//!
//! Adding a new stack = implement `Splitter` (and/or `Placer`), extend
//! the factory, done — the broker, chaos harness and scenario matrix pick
//! it up unchanged.

use crate::baselines::{GillisPolicy, McPolicy};
use crate::config::{ExperimentConfig, PolicyKind};
use crate::mab::{MabPolicy, Mode};
use crate::placement::{Assignment, BestFitPlacer, GradientPlacer, Placer, PlacementInput};
use crate::runtime::{Runtime, Surrogate};
use crate::sim::{CompletedTask, FailedTask, WorkerSnapshot};
use crate::splits::SplitDecision;
use crate::util::rng::Rng;
use crate::workload::trace::TraceBuffer;
use crate::workload::Task;

/// What a split decision may consult beyond the task itself. Carries the
/// broker's RNG so stochastic splitters draw from the same stream the
/// pre-trait broker used (fixed-seed trajectory parity).
pub struct SplitCtx<'a> {
    pub rng: &'a mut Rng,
}

/// A split-decision policy: decides per task, learns from the interval's
/// leaving tasks E_t and from failures.
pub trait Splitter {
    fn name(&self) -> &'static str;

    /// Take the split decision for an incoming task (Algorithm 1 line 9).
    fn decide(&mut self, task: &Task, ctx: &mut SplitCtx) -> SplitDecision;

    /// Interval bookkeeping with the leaving tasks. Returns `Some(O^MAB)`
    /// when the splitter defines its own interval objective (eq. 6); the
    /// broker substitutes the mean task reward otherwise.
    fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        let _ = leaving;
        None
    }

    /// Failed (abandoned) tasks — policies that track per-arm value can
    /// penalize the arm that stranded them.
    fn observe_failures(&mut self, failed: &[FailedTask]) {
        let _ = failed;
    }

    /// Total split decisions recorded by the policy's own counters, if it
    /// keeps any (the chaos `mab-accounting` oracle audits this against
    /// broker admissions).
    fn decision_count(&self) -> Option<u64> {
        None
    }

    /// Introspection for benches/examples that chart MAB internals
    /// (Fig. 6 curves). `None` for every non-MAB splitter.
    fn mab(&self) -> Option<&MabPolicy> {
        None
    }
}

/// MAB split decider (the paper's §4.1 contextual bandit).
pub struct MabSplitter {
    policy: MabPolicy,
}

impl Splitter for MabSplitter {
    fn name(&self) -> &'static str {
        "mab"
    }

    fn decide(&mut self, task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.policy.decide(task)
    }

    fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        Some(self.policy.observe_interval(leaving))
    }

    fn observe_failures(&mut self, failed: &[FailedTask]) {
        self.policy.observe_failures(failed);
    }

    fn decision_count(&self) -> Option<u64> {
        Some(self.policy.bandit.n.iter().flatten().sum::<u64>())
    }

    fn mab(&self) -> Option<&MabPolicy> {
        Some(&self.policy)
    }
}

/// Always the same decision (Layer+GOBI / Semantic+GOBI ablation rows).
pub struct FixedSplitter {
    decision: SplitDecision,
    name: &'static str,
}

impl Splitter for FixedSplitter {
    fn name(&self) -> &'static str {
        self.name
    }

    fn decide(&mut self, _task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.decision
    }
}

/// Uniform-random arm (the R+D ablation). Draws from the broker RNG via
/// [`SplitCtx`], preserving the pre-trait decision stream.
pub struct RandomSplitter;

impl Splitter for RandomSplitter {
    fn name(&self) -> &'static str {
        "random"
    }

    fn decide(&mut self, _task: &Task, ctx: &mut SplitCtx) -> SplitDecision {
        *ctx.rng.choice(&SplitDecision::ARMS)
    }
}

/// Gillis baseline: tabular Q-learning over layer/compressed actions.
pub struct GillisSplitter {
    policy: GillisPolicy,
}

impl Splitter for GillisSplitter {
    fn name(&self) -> &'static str {
        "gillis"
    }

    fn decide(&mut self, task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.policy.decide(task)
    }

    fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        self.policy.observe(leaving);
        None
    }
}

/// Model-compression baseline: every task runs the pruned single model.
#[derive(Default)]
pub struct McSplitter {
    policy: McPolicy,
}

impl Splitter for McSplitter {
    fn name(&self) -> &'static str {
        "mc"
    }

    fn decide(&mut self, task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.policy.decide(task)
    }
}

/// One composed policy stack: a splitter and a placer. This is the only
/// policy state the broker holds.
pub struct DecisionStack<'rt> {
    splitter: Box<dyn Splitter>,
    placer: Box<dyn Placer + 'rt>,
}

impl<'rt> DecisionStack<'rt> {
    pub fn new(splitter: Box<dyn Splitter>, placer: Box<dyn Placer + 'rt>) -> Self {
        DecisionStack { splitter, placer }
    }

    pub fn splitter_name(&self) -> &'static str {
        self.splitter.name()
    }

    pub fn placer_name(&self) -> &'static str {
        self.placer.name()
    }

    pub fn decide(&mut self, task: &Task, ctx: &mut SplitCtx) -> SplitDecision {
        self.splitter.decide(task, ctx)
    }

    pub fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        self.splitter.observe_interval(leaving)
    }

    pub fn observe_failures(&mut self, failed: &[FailedTask]) {
        self.splitter.observe_failures(failed);
    }

    pub fn decision_count(&self) -> Option<u64> {
        self.splitter.decision_count()
    }

    pub fn mab(&self) -> Option<&MabPolicy> {
        self.splitter.mab()
    }

    pub fn place(&mut self, input: &PlacementInput) -> Assignment {
        self.placer.place(input)
    }

    pub fn learned_placer(&self) -> bool {
        self.placer.is_learned()
    }

    pub fn observe_objective(
        &mut self,
        o_p: f64,
        trace: &mut TraceBuffer,
        steps: usize,
        rng: &mut Rng,
    ) {
        self.placer.observe_objective(o_p, trace, steps, rng);
    }

    pub fn featurize_idle(&self, snapshots: &[WorkerSnapshot]) -> Option<Vec<f32>> {
        self.placer.featurize_idle(snapshots)
    }

    pub fn pretrain_placer(
        &mut self,
        trace: &TraceBuffer,
        steps: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<()> {
        self.placer.pretrain(trace, steps, rng)
    }

    pub fn placer_stats(&self) -> Option<(usize, f32)> {
        self.placer.stats()
    }
}

impl PolicyKind {
    /// Factory: build the [`DecisionStack`] for this policy. `runtime` is
    /// required for the surrogate-based stacks (M+D, M+G, R+D, L+G, S+G);
    /// with `fallback_placer` they degrade to best-fit placement instead
    /// of erroring when the PJRT runtime is unavailable (the split
    /// decider is unaffected) — used by the chaos/matrix harnesses so
    /// fault-injection runs work without built artifacts.
    pub fn stack<'rt>(
        self,
        cfg: &ExperimentConfig,
        runtime: Option<&'rt Runtime>,
        mab_mode: Mode,
        fallback_placer: bool,
    ) -> anyhow::Result<DecisionStack<'rt>> {
        let splitter: Box<dyn Splitter> = match self {
            PolicyKind::MabDaso | PolicyKind::MabGobi => Box::new(MabSplitter {
                policy: MabPolicy::new(cfg.mab.clone(), mab_mode),
            }),
            PolicyKind::RandomDaso => Box::new(RandomSplitter),
            PolicyKind::LayerGobi => Box::new(FixedSplitter {
                decision: SplitDecision::Layer,
                name: "layer",
            }),
            PolicyKind::SemanticGobi => Box::new(FixedSplitter {
                decision: SplitDecision::Semantic,
                name: "semantic",
            }),
            PolicyKind::Gillis => Box::new(GillisSplitter {
                policy: GillisPolicy::new(cfg.mab.seed ^ 0x61),
            }),
            PolicyKind::ModelCompression => Box::new(McSplitter::default()),
        };

        let uses_gradient = matches!(
            self,
            PolicyKind::MabDaso
                | PolicyKind::MabGobi
                | PolicyKind::RandomDaso
                | PolicyKind::LayerGobi
                | PolicyKind::SemanticGobi
        );
        let placer: Box<dyn Placer + 'rt> = if uses_gradient {
            match runtime {
                Some(rt) => {
                    let surrogate = Surrogate::for_workers(rt, cfg.cluster.total_workers())?;
                    let decision_aware =
                        matches!(self, PolicyKind::MabDaso | PolicyKind::RandomDaso);
                    Box::new(GradientPlacer::new(
                        surrogate,
                        cfg.placement.clone(),
                        decision_aware,
                    ))
                }
                None if fallback_placer => {
                    crate::log_warn!(
                        "policy {:?}: PJRT runtime unavailable, degrading to best-fit placement",
                        self
                    );
                    Box::new(BestFitPlacer)
                }
                None => anyhow::bail!("policy {:?} needs the PJRT runtime (artifacts)", self),
            }
        } else {
            Box::new(BestFitPlacer)
        };

        Ok(DecisionStack { splitter, placer })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_policy_builds_a_stack_with_fallback() {
        let cfg = ExperimentConfig::small();
        for policy in PolicyKind::all() {
            let stack = policy.stack(&cfg, None, Mode::Test, true).unwrap();
            assert!(!stack.splitter_name().is_empty());
            assert_eq!(stack.placer_name(), "best-fit", "{policy:?} fallback placer");
            assert!(!stack.learned_placer());
            assert!(stack.placer_stats().is_none());
        }
    }

    #[test]
    fn gradient_stacks_error_without_runtime_unless_fallback() {
        let cfg = ExperimentConfig::small();
        for policy in [
            PolicyKind::MabDaso,
            PolicyKind::MabGobi,
            PolicyKind::RandomDaso,
            PolicyKind::LayerGobi,
            PolicyKind::SemanticGobi,
        ] {
            assert!(policy.stack(&cfg, None, Mode::Test, false).is_err(), "{policy:?}");
        }
        for policy in [PolicyKind::Gillis, PolicyKind::ModelCompression] {
            assert!(policy.stack(&cfg, None, Mode::Test, false).is_ok(), "{policy:?}");
        }
    }

    #[test]
    fn splitters_produce_their_documented_arms() {
        let cfg = ExperimentConfig::small();
        let mut rng = Rng::new(7);
        let task = Task {
            id: 1,
            app: crate::splits::App::Mnist,
            batch: 32_000,
            sla: 5.0,
            arrival_s: 0.0,
            decision: None,
        };
        let mut decide = |policy: PolicyKind| {
            let mut stack = policy.stack(&cfg, None, Mode::Test, true).unwrap();
            let mut ctx = SplitCtx { rng: &mut rng };
            stack.decide(&task, &mut ctx)
        };
        assert_eq!(decide(PolicyKind::LayerGobi), SplitDecision::Layer);
        assert_eq!(decide(PolicyKind::SemanticGobi), SplitDecision::Semantic);
        assert_eq!(decide(PolicyKind::ModelCompression), SplitDecision::Compressed);
        assert!(matches!(
            decide(PolicyKind::MabDaso),
            SplitDecision::Layer | SplitDecision::Semantic
        ));
        assert!(matches!(
            decide(PolicyKind::Gillis),
            SplitDecision::Layer | SplitDecision::Compressed
        ));
        for _ in 0..20 {
            assert!(SplitDecision::ARMS.contains(&decide(PolicyKind::RandomDaso)));
        }
    }

    #[test]
    fn mab_stack_exposes_introspection_and_counts() {
        let cfg = ExperimentConfig::small();
        let mut stack = PolicyKind::MabDaso.stack(&cfg, None, Mode::Test, true).unwrap();
        let warm = stack.decision_count().unwrap();
        let mut rng = Rng::new(1);
        let task = Task {
            id: 1,
            app: crate::splits::App::Mnist,
            batch: 32_000,
            sla: 5.0,
            arrival_s: 0.0,
            decision: None,
        };
        stack.decide(&task, &mut SplitCtx { rng: &mut rng });
        assert_eq!(stack.decision_count().unwrap(), warm + 1);
        assert!(stack.mab().is_some());
        // non-MAB stacks expose neither
        let mc = PolicyKind::ModelCompression.stack(&cfg, None, Mode::Test, true).unwrap();
        assert!(mc.decision_count().is_none());
        assert!(mc.mab().is_none());
    }
}
