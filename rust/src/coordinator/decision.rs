//! The decision plane: pluggable split/place policy stacks.
//!
//! The paper's claims are comparative — seven policy stacks race on
//! reward/ART/SLA (Table 4) — so the broker must treat "which policy" as
//! data, not structure. This module defines the two decision traits and
//! their composition:
//!
//! * [`Splitter`] — per-task split decision (MAB / fixed / random /
//!   Gillis RL / model compression) plus the interval feedback hooks
//!   (`observe_interval`, `observe_failures`);
//! * [`crate::placement::Placer`] — container placement plus the
//!   surrogate learning hooks (gradient DASO/GOBI or heuristics);
//! * [`DecisionStack`] — one splitter + one placer, built by the
//!   [`PolicyKind::stack`] factory. The broker holds exactly one stack
//!   and nothing policy-specific.
//!
//! Adding a new stack = implement `Splitter` (and/or `Placer`), extend
//! the factory, done — the broker, chaos harness and scenario matrix pick
//! it up unchanged.

use crate::baselines::{GillisPolicy, McPolicy};
use crate::cluster::build_fleet;
use crate::config::{ExperimentConfig, MabConfig, PolicyKind};
use crate::mab::{MabPolicy, Mode};
use crate::placement::{
    Assignment, BestFitPlacer, EnergyAwarePlacer, GradientPlacer, Placer, PlacementInput,
};
use crate::runtime::{Runtime, Surrogate};
use crate::sim::{CompletedTask, FailedTask, WorkerSnapshot, RAM_OVERCOMMIT};
use crate::splits::{App, Precedence, Registry, SplitDecision, APPS};
use crate::util::rng::Rng;
use crate::util::stats::Ema;
use crate::workload::trace::TraceBuffer;
use crate::workload::Task;

/// What a split decision may consult beyond the task itself. Carries the
/// broker's RNG so stochastic splitters draw from the same stream the
/// pre-trait broker used (fixed-seed trajectory parity).
pub struct SplitCtx<'a> {
    pub rng: &'a mut Rng,
}

/// A split-decision policy: decides per task, learns from the interval's
/// leaving tasks E_t and from failures.
pub trait Splitter {
    fn name(&self) -> &'static str;

    /// Take the split decision for an incoming task (Algorithm 1 line 9).
    fn decide(&mut self, task: &Task, ctx: &mut SplitCtx) -> SplitDecision;

    /// Interval bookkeeping with the leaving tasks. Returns `Some(O^MAB)`
    /// when the splitter defines its own interval objective (eq. 6); the
    /// broker substitutes the mean task reward otherwise.
    fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        let _ = leaving;
        None
    }

    /// Failed (abandoned) tasks — policies that track per-arm value can
    /// penalize the arm that stranded them.
    fn observe_failures(&mut self, failed: &[FailedTask]) {
        let _ = failed;
    }

    /// Total split decisions recorded by the policy's own counters, if it
    /// keeps any (the chaos `mab-accounting` oracle audits this against
    /// broker admissions).
    fn decision_count(&self) -> Option<u64> {
        None
    }

    /// Introspection for benches/examples that chart MAB internals
    /// (Fig. 6 curves). `None` for every non-MAB splitter.
    fn mab(&self) -> Option<&MabPolicy> {
        None
    }
}

/// MAB split decider (the paper's §4.1 contextual bandit).
pub struct MabSplitter {
    policy: MabPolicy,
}

impl Splitter for MabSplitter {
    fn name(&self) -> &'static str {
        "mab"
    }

    fn decide(&mut self, task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.policy.decide(task)
    }

    fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        Some(self.policy.observe_interval(leaving))
    }

    fn observe_failures(&mut self, failed: &[FailedTask]) {
        self.policy.observe_failures(failed);
    }

    fn decision_count(&self) -> Option<u64> {
        Some(self.policy.bandit.n.iter().flatten().sum::<u64>())
    }

    fn mab(&self) -> Option<&MabPolicy> {
        Some(&self.policy)
    }
}

/// Always the same decision (Layer+GOBI / Semantic+GOBI ablation rows).
pub struct FixedSplitter {
    decision: SplitDecision,
    name: &'static str,
}

impl Splitter for FixedSplitter {
    fn name(&self) -> &'static str {
        self.name
    }

    fn decide(&mut self, _task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.decision
    }
}

/// Uniform-random arm (the R+D ablation). Draws from the broker RNG via
/// [`SplitCtx`], preserving the pre-trait decision stream.
pub struct RandomSplitter;

impl Splitter for RandomSplitter {
    fn name(&self) -> &'static str {
        "random"
    }

    fn decide(&mut self, _task: &Task, ctx: &mut SplitCtx) -> SplitDecision {
        *ctx.rng.choice(&SplitDecision::ARMS)
    }
}

/// Gillis baseline: tabular Q-learning over layer/compressed actions.
pub struct GillisSplitter {
    policy: GillisPolicy,
}

impl Splitter for GillisSplitter {
    fn name(&self) -> &'static str {
        "gillis"
    }

    fn decide(&mut self, task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.policy.decide(task)
    }

    fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        self.policy.observe(leaving);
        None
    }
}

/// Model-compression baseline: every task runs the pruned single model.
#[derive(Default)]
pub struct McSplitter {
    policy: McPolicy,
}

impl Splitter for McSplitter {
    fn name(&self) -> &'static str {
        "mc"
    }

    fn decide(&mut self, task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.policy.decide(task)
    }
}

/// Contention factor the latency-memory cost model applies on top of the
/// zero-queue MIPS estimate (the registry's calibration: nominal response
/// under typical load is roughly twice the bare compute time).
const LATMEM_CONTENTION: f64 = 2.0;

/// Latency-memory optimized splitting (arXiv:2107.09123, adapted to the
/// engine's MIPS/RAM calibration): score both arms per task by (a) the
/// split plan's estimated resident-RAM footprint against the fleet's
/// memory and (b) a pipeline-latency estimate against the task's deadline.
/// Memory-infeasible arms are never picked while a feasible one exists;
/// among deadline-meeting arms the lighter plan wins, otherwise the faster
/// one. Latency estimates warm-start from the MIPS cost model and track
/// observed responses through the interval learning hooks.
pub struct LatMemSplitter {
    /// Per (app, arm) response-time EMA in scheduling intervals,
    /// normalized to a 40k-sample batch like the MAB's R^a estimates.
    ema: [[Ema; 2]; 3],
    /// Total physical fleet RAM (MB) — the budget split plans are scored
    /// against ("free RAM" proxy: the splitter cannot see engine state).
    fleet_ram_mb: f64,
    /// Largest worker's RAM × overcommit: a single fragment bigger than
    /// this fits nowhere, whatever the fleet total says.
    max_fragment_mb: f64,
    decisions: u64,
}

impl LatMemSplitter {
    pub fn new(cfg: &ExperimentConfig) -> Self {
        let fleet = build_fleet(&cfg.cluster);
        let mean_mips = fleet.total_mips() / fleet.len().max(1) as f64;
        let max_worker_ram =
            fleet.workers.iter().map(|w| w.spec.ram_mb).fold(0.0, f64::max);
        let mut ema = [[Ema::new(cfg.mab.phi); 2]; 3];
        for app in APPS {
            for d in SplitDecision::ARMS {
                let prior = Self::cost_model_intervals(
                    app,
                    d,
                    mean_mips,
                    cfg.sim.interval_seconds,
                );
                ema[app.index()][d.arm_index()] = Ema::with_initial(cfg.mab.phi, prior);
            }
        }
        LatMemSplitter {
            ema,
            fleet_ram_mb: fleet.total_ram_mb(),
            max_fragment_mb: max_worker_ram * RAM_OVERCOMMIT,
            decisions: 0,
        }
    }

    /// Zero-state pipeline-latency prior (intervals, 40k batch): critical
    /// path MI — chain sums fragments, parallel is straggler-bound by the
    /// heaviest — over the fleet's mean MIPS, under typical contention.
    fn cost_model_intervals(
        app: App,
        d: SplitDecision,
        mean_mips: f64,
        interval_s: f64,
    ) -> f64 {
        let plan = Registry::plan(app, d);
        let per_ksample = match plan.precedence {
            Precedence::Chain => plan.fragments.iter().map(|f| f.mi_per_ksample).sum(),
            Precedence::Parallel => {
                plan.fragments.iter().map(|f| f.mi_per_ksample).fold(0.0, f64::max)
            }
        };
        LATMEM_CONTENTION * per_ksample * 40.0 / mean_mips.max(1.0) / interval_s
    }

    /// Estimated resident RAM of the whole split plan for (app, batch, d)
    /// and of its largest single fragment, in MB.
    pub fn estimated_ram_mb(app: App, batch: u64, d: SplitDecision) -> (f64, f64) {
        let plan = Registry::plan(app, d);
        let k = batch as f64 / 1000.0;
        let mut total = 0.0;
        let mut largest = 0.0f64;
        for f in &plan.fragments {
            let ram = f.ram_fixed_mb + f.ram_per_ksample_mb * k;
            total += ram;
            largest = largest.max(ram);
        }
        (total, largest)
    }

    /// Does the arm's estimated footprint fit the fleet? The whole plan
    /// must fit the fleet's total RAM and every fragment must fit on the
    /// largest worker (with overcommit).
    pub fn fits_fleet(&self, app: App, batch: u64, d: SplitDecision) -> bool {
        let (total, largest) = Self::estimated_ram_mb(app, batch, d);
        total <= self.fleet_ram_mb && largest <= self.max_fragment_mb
    }

    /// Current latency estimate for (app, arm) scaled to the task's batch.
    fn latency_estimate(&self, app: App, batch: u64, d: SplitDecision) -> f64 {
        self.ema[app.index()][d.arm_index()].get_or(0.0) * batch as f64 / 40_000.0
    }
}

impl Splitter for LatMemSplitter {
    fn name(&self) -> &'static str {
        "latmem"
    }

    fn decide(&mut self, task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.decisions += 1;
        let any_fits =
            SplitDecision::ARMS.iter().any(|&d| self.fits_fleet(task.app, task.batch, d));
        // candidates: memory-feasible arms; every arm only when none fits
        // (least-bad fallback — the structural guarantee is "never pick an
        // infeasible arm while a feasible one exists").
        let mut best: Option<(SplitDecision, bool, f64, f64)> = None;
        for &d in &SplitDecision::ARMS {
            if any_fits && !self.fits_fleet(task.app, task.batch, d) {
                continue;
            }
            let lat = self.latency_estimate(task.app, task.batch, d);
            let (ram, _) = Self::estimated_ram_mb(task.app, task.batch, d);
            let meets = lat <= task.sla;
            let better = match best {
                None => true,
                Some((_, best_meets, best_lat, best_ram)) => match (meets, best_meets) {
                    (true, false) => true,
                    (false, true) => false,
                    // both meet the deadline: lighter memory footprint wins
                    (true, true) => ram < best_ram,
                    // neither meets: faster pipeline wins
                    (false, false) => lat < best_lat,
                },
            };
            if better {
                best = Some((d, meets, lat, ram));
            }
        }
        best.map(|(d, ..)| d).unwrap_or(SplitDecision::Layer)
    }

    fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        for t in leaving {
            if matches!(t.decision, SplitDecision::Layer | SplitDecision::Semantic) {
                let size = t.batch as f64 / 40_000.0;
                self.ema[t.app.index()][t.decision.arm_index()].push(t.response / size);
            }
        }
        None
    }

    fn observe_failures(&mut self, failed: &[FailedTask]) {
        // an abandoned task is evidence the arm's pipeline ran long: feed
        // its age (≥ the timeout) back as a pessimistic response sample
        for t in failed {
            if matches!(t.decision, SplitDecision::Layer | SplitDecision::Semantic) {
                let size = t.batch as f64 / 40_000.0;
                self.ema[t.app.index()][t.decision.arm_index()].push(t.age / size);
            }
        }
    }

    fn decision_count(&self) -> Option<u64> {
        Some(self.decisions)
    }
}

/// Deterministic probe cadence for [`OnlineSplitSplitter`]: every Nth
/// decision tries the non-favored arm so its violation EMA stays fresh.
/// Counter-driven (no RNG), so decision streams replay byte-identically.
const ONLINE_PROBE_EVERY: u64 = 7;
/// Hysteresis cap on the learned switching cutoff.
const ONLINE_CUTOFF_MAX: f64 = 0.5;

/// Online model splitting for device-edge co-inference (arXiv:2105.13618):
/// track a running deadline-violation EMA per strategy and switch the
/// favored arm when the current one's violation rate exceeds the other's
/// by a learned cutoff. The cutoff doubles after every switch (hysteresis
/// against thrashing) and decays back toward its floor each interval, so
/// the policy stays reactive in volatile regimes without oscillating.
pub struct OnlineSplitSplitter {
    /// Per-arm deadline-violation EMA ∈ [0,1] (failures count as 1).
    viol: [Ema; 2],
    /// The arm currently favored (starts at Layer, the accuracy edge).
    current: SplitDecision,
    /// Learned switching threshold on the violation-rate gap.
    cutoff: f64,
    /// Cutoff floor (initial value, decay target).
    cutoff0: f64,
    /// Adaptation rate for cutoff decay (the paper family's k).
    k: f64,
    decisions: u64,
    /// Arm switches taken so far (introspection for tests/benches).
    pub switches: u64,
}

impl OnlineSplitSplitter {
    pub fn new(cfg: &MabConfig) -> Self {
        OnlineSplitSplitter {
            // slow EMA: newest sample weighted (1 − φ) so one bad interval
            // does not flip the strategy
            viol: [Ema::with_initial(1.0 - cfg.phi, 0.0); 2],
            current: SplitDecision::Layer,
            cutoff: cfg.rho0,
            cutoff0: cfg.rho0,
            k: cfg.k,
            decisions: 0,
            switches: 0,
        }
    }

    fn other(d: SplitDecision) -> SplitDecision {
        match d {
            SplitDecision::Layer => SplitDecision::Semantic,
            _ => SplitDecision::Layer,
        }
    }

    /// Current violation-rate estimate of an arm (tests/benches).
    pub fn violation_rate(&self, d: SplitDecision) -> f64 {
        self.viol[d.arm_index()].get_or(0.0)
    }
}

impl Splitter for OnlineSplitSplitter {
    fn name(&self) -> &'static str {
        "onlinesplit"
    }

    fn decide(&mut self, _task: &Task, _ctx: &mut SplitCtx) -> SplitDecision {
        self.decisions += 1;
        if self.decisions % ONLINE_PROBE_EVERY == 0 {
            Self::other(self.current)
        } else {
            self.current
        }
    }

    fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        for t in leaving {
            if matches!(t.decision, SplitDecision::Layer | SplitDecision::Semantic) {
                let violated = if t.response > t.sla { 1.0 } else { 0.0 };
                self.viol[t.decision.arm_index()].push(violated);
            }
        }
        // cutoff decays toward its floor, then the switch rule fires —
        // decay first so a long-stable cutoff is cheap to cross again
        self.cutoff = self.cutoff0.max(self.cutoff * (1.0 - self.k));
        let cur = self.current.arm_index();
        let alt = 1 - cur;
        if self.viol[cur].get_or(0.0) > self.viol[alt].get_or(0.0) + self.cutoff {
            self.current = Self::other(self.current);
            self.switches += 1;
            self.cutoff = (self.cutoff * 2.0).min(ONLINE_CUTOFF_MAX);
        }
        None
    }

    fn observe_failures(&mut self, failed: &[FailedTask]) {
        for t in failed {
            if matches!(t.decision, SplitDecision::Layer | SplitDecision::Semantic) {
                self.viol[t.decision.arm_index()].push(1.0);
            }
        }
    }

    fn decision_count(&self) -> Option<u64> {
        Some(self.decisions)
    }
}

/// One composed policy stack: a splitter and a placer. This is the only
/// policy state the broker holds.
pub struct DecisionStack<'rt> {
    splitter: Box<dyn Splitter>,
    placer: Box<dyn Placer + 'rt>,
}

impl<'rt> DecisionStack<'rt> {
    pub fn new(splitter: Box<dyn Splitter>, placer: Box<dyn Placer + 'rt>) -> Self {
        DecisionStack { splitter, placer }
    }

    pub fn splitter_name(&self) -> &'static str {
        self.splitter.name()
    }

    pub fn placer_name(&self) -> &'static str {
        self.placer.name()
    }

    pub fn decide(&mut self, task: &Task, ctx: &mut SplitCtx) -> SplitDecision {
        self.splitter.decide(task, ctx)
    }

    pub fn observe_interval(&mut self, leaving: &[CompletedTask]) -> Option<f64> {
        self.splitter.observe_interval(leaving)
    }

    pub fn observe_failures(&mut self, failed: &[FailedTask]) {
        self.splitter.observe_failures(failed);
    }

    pub fn decision_count(&self) -> Option<u64> {
        self.splitter.decision_count()
    }

    pub fn mab(&self) -> Option<&MabPolicy> {
        self.splitter.mab()
    }

    pub fn place(&mut self, input: &PlacementInput) -> Assignment {
        self.placer.place(input)
    }

    pub fn learned_placer(&self) -> bool {
        self.placer.is_learned()
    }

    pub fn observe_objective(
        &mut self,
        o_p: f64,
        trace: &mut TraceBuffer,
        steps: usize,
        rng: &mut Rng,
    ) {
        self.placer.observe_objective(o_p, trace, steps, rng);
    }

    pub fn featurize_idle(&self, snapshots: &[WorkerSnapshot]) -> Option<Vec<f32>> {
        self.placer.featurize_idle(snapshots)
    }

    pub fn pretrain_placer(
        &mut self,
        trace: &TraceBuffer,
        steps: usize,
        rng: &mut Rng,
    ) -> anyhow::Result<()> {
        self.placer.pretrain(trace, steps, rng)
    }

    pub fn placer_stats(&self) -> Option<(usize, f32)> {
        self.placer.stats()
    }

    /// Forward the `--paranoid` twin switch to the placer (see
    /// [`Placer::set_paranoid`]).
    pub fn set_placer_paranoid(&mut self, on: bool) {
        self.placer.set_paranoid(on);
    }

    /// Drain the placer's recorded index-vs-scan divergences.
    pub fn take_placer_divergences(&mut self) -> Vec<String> {
        self.placer.take_paranoid_divergences()
    }
}

impl PolicyKind {
    /// Factory: build the [`DecisionStack`] for this policy. `runtime` is
    /// required for the surrogate-based stacks (M+D, M+G, R+D, L+G, S+G);
    /// with `fallback_placer` they degrade to best-fit placement instead
    /// of erroring when the PJRT runtime is unavailable (the split
    /// decider is unaffected) — used by the chaos/matrix harnesses so
    /// fault-injection runs work without built artifacts.
    pub fn stack<'rt>(
        self,
        cfg: &ExperimentConfig,
        runtime: Option<&'rt Runtime>,
        mab_mode: Mode,
        fallback_placer: bool,
    ) -> anyhow::Result<DecisionStack<'rt>> {
        let splitter: Box<dyn Splitter> = match self {
            PolicyKind::MabDaso | PolicyKind::MabGobi => Box::new(MabSplitter {
                policy: MabPolicy::new(cfg.mab.clone(), mab_mode),
            }),
            PolicyKind::RandomDaso => Box::new(RandomSplitter),
            PolicyKind::LayerGobi => Box::new(FixedSplitter {
                decision: SplitDecision::Layer,
                name: "layer",
            }),
            PolicyKind::SemanticGobi => Box::new(FixedSplitter {
                decision: SplitDecision::Semantic,
                name: "semantic",
            }),
            PolicyKind::Gillis => Box::new(GillisSplitter {
                policy: GillisPolicy::new(cfg.mab.seed ^ 0x61),
            }),
            PolicyKind::ModelCompression => Box::new(McSplitter::default()),
            // energy-fit is a placement-side policy: it reuses the MC
            // splitter so the energyfit~mc differential isolates the
            // placer's contribution to AEC
            PolicyKind::EnergyFit => Box::new(McSplitter::default()),
            PolicyKind::LatMem => Box::new(LatMemSplitter::new(cfg)),
            PolicyKind::OnlineSplit => Box::new(OnlineSplitSplitter::new(&cfg.mab)),
        };

        let uses_gradient = matches!(
            self,
            PolicyKind::MabDaso
                | PolicyKind::MabGobi
                | PolicyKind::RandomDaso
                | PolicyKind::LayerGobi
                | PolicyKind::SemanticGobi
        );
        let placer: Box<dyn Placer + 'rt> = if uses_gradient {
            match runtime {
                Some(rt) => {
                    let surrogate = Surrogate::for_workers(rt, cfg.cluster.total_workers())?;
                    let decision_aware =
                        matches!(self, PolicyKind::MabDaso | PolicyKind::RandomDaso);
                    Box::new(GradientPlacer::new(
                        surrogate,
                        cfg.placement.clone(),
                        decision_aware,
                    ))
                }
                None if fallback_placer => {
                    crate::log_warn!(
                        "policy {:?}: PJRT runtime unavailable, degrading to best-fit placement",
                        self
                    );
                    Box::new(BestFitPlacer::new())
                }
                None => anyhow::bail!("policy {:?} needs the PJRT runtime (artifacts)", self),
            }
        } else if matches!(self, PolicyKind::EnergyFit) {
            // marginal watts per worker (peak − idle of its node type),
            // fixed at stack build — the placement input carries no specs
            let fleet = build_fleet(&cfg.cluster);
            let watts: Vec<f64> = fleet
                .workers
                .iter()
                .map(|w| w.spec.peak_watts - w.spec.idle_watts)
                .collect();
            Box::new(EnergyAwarePlacer::new(&watts))
        } else {
            Box::new(BestFitPlacer::new())
        };

        Ok(DecisionStack { splitter, placer })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_policy_builds_a_stack_with_fallback() {
        let cfg = ExperimentConfig::small();
        for policy in PolicyKind::all() {
            let stack = policy.stack(&cfg, None, Mode::Test, true).unwrap();
            assert!(!stack.splitter_name().is_empty());
            let placer = if matches!(policy, PolicyKind::EnergyFit) {
                "energy-fit"
            } else {
                "best-fit"
            };
            assert_eq!(stack.placer_name(), placer, "{policy:?} fallback placer");
            assert!(!stack.learned_placer());
            assert!(stack.placer_stats().is_none());
        }
    }

    #[test]
    fn gradient_stacks_error_without_runtime_unless_fallback() {
        let cfg = ExperimentConfig::small();
        for policy in [
            PolicyKind::MabDaso,
            PolicyKind::MabGobi,
            PolicyKind::RandomDaso,
            PolicyKind::LayerGobi,
            PolicyKind::SemanticGobi,
        ] {
            assert!(policy.stack(&cfg, None, Mode::Test, false).is_err(), "{policy:?}");
        }
        for policy in [
            PolicyKind::Gillis,
            PolicyKind::ModelCompression,
            PolicyKind::EnergyFit,
            PolicyKind::LatMem,
            PolicyKind::OnlineSplit,
        ] {
            assert!(policy.stack(&cfg, None, Mode::Test, false).is_ok(), "{policy:?}");
        }
    }

    #[test]
    fn splitters_produce_their_documented_arms() {
        let cfg = ExperimentConfig::small();
        let mut rng = Rng::new(7);
        let task = Task {
            id: 1,
            app: crate::splits::App::Mnist,
            batch: 32_000,
            sla: 5.0,
            arrival_s: 0.0,
            decision: None,
        };
        let mut decide = |policy: PolicyKind| {
            let mut stack = policy.stack(&cfg, None, Mode::Test, true).unwrap();
            let mut ctx = SplitCtx { rng: &mut rng };
            stack.decide(&task, &mut ctx)
        };
        assert_eq!(decide(PolicyKind::LayerGobi), SplitDecision::Layer);
        assert_eq!(decide(PolicyKind::SemanticGobi), SplitDecision::Semantic);
        assert_eq!(decide(PolicyKind::ModelCompression), SplitDecision::Compressed);
        assert!(matches!(
            decide(PolicyKind::MabDaso),
            SplitDecision::Layer | SplitDecision::Semantic
        ));
        assert!(matches!(
            decide(PolicyKind::Gillis),
            SplitDecision::Layer | SplitDecision::Compressed
        ));
        for _ in 0..20 {
            assert!(SplitDecision::ARMS.contains(&decide(PolicyKind::RandomDaso)));
        }
        // the related-work splitters stay within the two split arms
        assert!(SplitDecision::ARMS.contains(&decide(PolicyKind::LatMem)));
        assert!(SplitDecision::ARMS.contains(&decide(PolicyKind::OnlineSplit)));
    }

    #[test]
    fn mab_stack_exposes_introspection_and_counts() {
        let cfg = ExperimentConfig::small();
        let mut stack = PolicyKind::MabDaso.stack(&cfg, None, Mode::Test, true).unwrap();
        let warm = stack.decision_count().unwrap();
        let mut rng = Rng::new(1);
        let task = Task {
            id: 1,
            app: crate::splits::App::Mnist,
            batch: 32_000,
            sla: 5.0,
            arrival_s: 0.0,
            decision: None,
        };
        stack.decide(&task, &mut SplitCtx { rng: &mut rng });
        assert_eq!(stack.decision_count().unwrap(), warm + 1);
        assert!(stack.mab().is_some());
        // non-MAB stacks expose neither
        let mc = PolicyKind::ModelCompression.stack(&cfg, None, Mode::Test, true).unwrap();
        assert!(mc.decision_count().is_none());
        assert!(mc.mab().is_none());
    }

    fn task_of(app: crate::splits::App, batch: u64, sla: f64) -> Task {
        Task { id: 1, app, batch, sla, arrival_s: 0.0, decision: None }
    }

    fn done(d: SplitDecision, response: f64, sla: f64) -> CompletedTask {
        CompletedTask {
            task_id: 0,
            app: crate::splits::App::Mnist,
            decision: d,
            batch: 40_000,
            sla,
            response,
            wait: 0.0,
            exec: response,
            transfer: 0.0,
            migrate: 0.0,
            workers: vec![0],
            accuracy: 0.95,
        }
    }

    /// On a fleet where the semantic fan-out's estimated RAM exceeds the
    /// total fleet RAM but the layer chain fits, LatMem must take the
    /// chain even though semantic wins on latency — memory feasibility
    /// overrides the latency preference (never the other way around).
    #[test]
    fn latmem_memory_feasibility_overrides_latency() {
        use crate::config::EnvConstraint;
        use crate::splits::App;
        // a CIFAR100 33k batch on one memory-constrained B2ms: semantic
        // (4 × ~539 MB = ~2156 MB) exceeds the 2147.5 MB fleet RAM, the
        // layer chain (~2083 MB) fits
        let mut tight = ExperimentConfig::small();
        tight.cluster.counts = [1, 0, 0, 0];
        tight.cluster.constraint = EnvConstraint::Memory;
        let task = task_of(App::Cifar100, 33_000, 0.5); // deadline unmeetable
        let mut s = LatMemSplitter::new(&tight);
        assert!(!s.fits_fleet(App::Cifar100, 33_000, SplitDecision::Semantic));
        assert!(s.fits_fleet(App::Cifar100, 33_000, SplitDecision::Layer));
        let mut rng = Rng::new(1);
        let d = s.decide(&task, &mut SplitCtx { rng: &mut rng });
        assert_eq!(d, SplitDecision::Layer, "infeasible semantic must not be picked");
        // same task on the normal small fleet: both fit, neither meets the
        // 0.5-interval deadline, so the faster semantic fan-out wins
        let mut roomy = LatMemSplitter::new(&ExperimentConfig::small());
        let d = roomy.decide(&task, &mut SplitCtx { rng: &mut rng });
        assert_eq!(d, SplitDecision::Semantic, "latency preference without the squeeze");
    }

    /// With a generous deadline both arms qualify and the lighter plan
    /// (semantic for MNIST) wins the memory score.
    #[test]
    fn latmem_prefers_lighter_plan_when_both_meet_deadline() {
        use crate::splits::App;
        let mut s = LatMemSplitter::new(&ExperimentConfig::small());
        let mut rng = Rng::new(1);
        let d = s.decide(&task_of(App::Mnist, 32_000, 50.0), &mut SplitCtx { rng: &mut rng });
        assert_eq!(d, SplitDecision::Semantic);
        // learning hook: heavy observed semantic responses push the EMA up
        let before = s.latency_estimate(App::Mnist, 40_000, SplitDecision::Semantic);
        s.observe_interval(&[done(SplitDecision::Semantic, 20.0, 5.0)]);
        assert!(s.latency_estimate(App::Mnist, 40_000, SplitDecision::Semantic) > before);
    }

    /// The online policy starts on the layer arm, probes the other arm on
    /// a fixed cadence, and switches once the favored arm's violation EMA
    /// exceeds the alternative's by the learned cutoff.
    #[test]
    fn online_split_switches_on_violation_gap_and_probes() {
        let cfg = ExperimentConfig::small();
        let mut s = OnlineSplitSplitter::new(&cfg.mab);
        let mut rng = Rng::new(1);
        let t = task_of(crate::splits::App::Mnist, 40_000, 5.0);
        // decisions 1..6 favor Layer; the 7th probes Semantic
        for _ in 0..6 {
            assert_eq!(s.decide(&t, &mut SplitCtx { rng: &mut rng }), SplitDecision::Layer);
        }
        assert_eq!(s.decide(&t, &mut SplitCtx { rng: &mut rng }), SplitDecision::Semantic);
        // violating layer completions drag the layer EMA up until the gap
        // crosses the cutoff and the policy switches
        for _ in 0..5 {
            s.observe_interval(&[done(SplitDecision::Layer, 9.0, 5.0)]);
        }
        assert!(s.switches >= 1, "violation gap must trigger a switch");
        assert!(s.violation_rate(SplitDecision::Layer) > s.violation_rate(SplitDecision::Semantic));
        assert_eq!(s.decide(&t, &mut SplitCtx { rng: &mut rng }), SplitDecision::Semantic);
        // failures count as violations for the chosen arm
        let before = s.violation_rate(SplitDecision::Semantic);
        s.observe_failures(&[FailedTask {
            task_id: 9,
            app: crate::splits::App::Mnist,
            decision: SplitDecision::Semantic,
            batch: 40_000,
            sla: 5.0,
            age: 40.0,
        }]);
        assert!(s.violation_rate(SplitDecision::Semantic) > before);
    }

    /// Both new stacks keep their own decision counters (the chaos
    /// `mab-accounting` oracle audits these against broker admissions).
    #[test]
    fn new_splitter_stacks_count_decisions() {
        let cfg = ExperimentConfig::small();
        for policy in [PolicyKind::LatMem, PolicyKind::OnlineSplit] {
            let mut stack = policy.stack(&cfg, None, Mode::Test, true).unwrap();
            assert_eq!(stack.decision_count(), Some(0), "{policy:?}");
            assert!(stack.mab().is_none(), "{policy:?}");
            let mut rng = Rng::new(1);
            let t = task_of(crate::splits::App::Mnist, 32_000, 5.0);
            stack.decide(&t, &mut SplitCtx { rng: &mut rng });
            stack.decide(&t, &mut SplitCtx { rng: &mut rng });
            assert_eq!(stack.decision_count(), Some(2), "{policy:?}");
        }
    }
}
