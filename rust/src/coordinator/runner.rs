//! Experiment runner: builds a broker for a config, optionally pre-trains
//! the surrogate, runs Γ intervals and returns metrics + summary.

use anyhow::Result;

use crate::config::{ExperimentConfig, PolicyKind};
use crate::mab::Mode;
use crate::metrics::{Metrics, Summary};
use crate::runtime::Runtime;

use super::broker::Broker;

/// Everything a bench needs from one run.
pub struct ExperimentOutput {
    pub metrics: Metrics,
    pub summary: Summary,
}

/// Surrogate pre-training budget for gradient policies (intervals of
/// trace collection, Adam steps).
const PRETRAIN_INTERVALS: usize = 10;
const PRETRAIN_STEPS: usize = 30;

/// Run one experiment. `runtime` may be None only for Gillis/MC.
pub fn run_experiment(
    cfg: ExperimentConfig,
    runtime: Option<&Runtime>,
) -> Result<ExperimentOutput> {
    let policy_name = cfg.policy.name().to_string();
    let needs_pretrain = matches!(
        cfg.policy,
        PolicyKind::MabDaso
            | PolicyKind::MabGobi
            | PolicyKind::RandomDaso
            | PolicyKind::LayerGobi
            | PolicyKind::SemanticGobi
    );
    let mut broker = Broker::new(cfg, runtime, Mode::Test)?;
    if needs_pretrain {
        broker.pretrain(PRETRAIN_INTERVALS, PRETRAIN_STEPS)?;
    }
    broker.run();
    let summary = broker.metrics.summary(&policy_name);
    Ok(ExperimentOutput { metrics: broker.metrics, summary })
}

/// Locate the artifacts directory: `$SPLITPLACE_ARTIFACTS`, else
/// `<manifest dir>/artifacts`, else `./artifacts`.
pub fn artifacts_dir() -> String {
    if let Ok(d) = std::env::var("SPLITPLACE_ARTIFACTS") {
        return d;
    }
    let repo = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(repo).join("manifest.json").exists() {
        return repo.to_string();
    }
    "artifacts".to_string()
}

/// Load the runtime if artifacts exist (shared helper for benches/examples).
pub fn try_runtime() -> Option<Runtime> {
    let dir = artifacts_dir();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        return None;
    }
    Runtime::load(&dir).ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AccuracyMode, ExperimentConfig};

    #[test]
    fn full_splitplace_run_with_artifacts() {
        let Some(rt) = try_runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut cfg = ExperimentConfig::small();
        cfg.policy = PolicyKind::MabDaso;
        cfg.sim.intervals = 12;
        cfg.accuracy = AccuracyMode::Manifest;
        let out = run_experiment(cfg, Some(&rt)).unwrap();
        assert!(out.summary.tasks > 0);
        assert!(out.summary.avg_reward > 0.2, "reward {}", out.summary.avg_reward);
        assert!(out.summary.accuracy > 0.5);
        assert!(out.summary.response.0 > 0.0);
    }

    #[test]
    fn splitplace_beats_always_layer_on_tight_slas() {
        let Some(rt) = try_runtime() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let run = |policy| {
            let mut cfg = ExperimentConfig::small();
            cfg.policy = policy;
            cfg.sim.intervals = 25;
            cfg.workload.lambda = 3.0;
            // bias toward tight SLAs so layer-only violates a lot
            cfg.workload.sla_lo = 0.4;
            cfg.workload.sla_hi = 1.2;
            run_experiment(cfg, Some(&rt)).unwrap().summary
        };
        let md = run(PolicyKind::MabDaso);
        let lg = run(PolicyKind::LayerGobi);
        assert!(
            md.sla_violations <= lg.sla_violations + 0.05,
            "M+D {} vs L+G {}",
            md.sla_violations,
            lg.sla_violations
        );
    }
}
