//! The broker: SplitPlace's Algorithm 1 plus the baseline policy loops.

pub mod broker;
pub mod oracle;
pub mod runner;

pub use broker::Broker;
pub use oracle::AccuracyOracle;
pub use runner::{run_experiment, ExperimentOutput};
