//! The broker: SplitPlace's Algorithm 1 plus the pluggable decision plane.

pub mod broker;
pub mod decision;
pub mod oracle;
pub mod runner;

pub use broker::Broker;
pub use decision::{
    DecisionStack, LatMemSplitter, OnlineSplitSplitter, SplitCtx, Splitter,
};
pub use oracle::AccuracyOracle;
pub use runner::{run_experiment, ExperimentOutput};
