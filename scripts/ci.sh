#!/usr/bin/env bash
# CI entrypoint: build, test, and a fixed-seed chaos smoke run so fault
# handling (crash/requeue/re-place + invariant oracles) is exercised on
# every PR. Fails on any oracle violation (chaos exits non-zero).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== chaos smoke (fixed seed, light profile) =="
./target/release/splitplace chaos --seed 7 --profile light --intervals 10 --policy mc

echo "== chaos smoke (fixed seed, heavy profile, differential) =="
./target/release/splitplace chaos --seed 7 --profile heavy --intervals 10 \
    --policy mab-daso --differential layer-gobi

echo "CI OK"
