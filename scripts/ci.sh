#!/usr/bin/env bash
# CI entrypoint: lint, build, test, a fixed-seed chaos smoke, and the
# scenario matrix smoke (policy × scenario × seed cross product with
# golden-trace gating, including differential policy-pair cells). Fails on
# any oracle violation, Table-4 ordering failure, lint warning or golden
# drift. Budget: the post-build steps stay well under ~2 minutes.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
# NOTE: tests/parity.rs self-bootstraps tests/goldens/parity/*.json on a
# tree that has none — commit the generated files after reviewing them.
cargo test -q

echo "== chaos smoke (fixed seed, light profile) =="
./target/release/splitplace chaos --seed 7 --profile light --intervals 10 --policy mc

echo "== chaos smoke (fixed seed, heavy profile, differential) =="
./target/release/splitplace chaos --seed 7 --profile heavy --intervals 10 \
    --policy mab-daso --differential layer-gobi

echo "== matrix smoke (parallel cells, golden gate, bug-base) =="
# First run on a machine with no recorded goldens: bootstrap them with a
# serial run (review + commit the diff under tests/goldens/). The parallel
# gate right after must then match byte-for-byte, which exercises the
# --jobs 1 == --jobs N determinism contract end-to-end on every CI run.
# The smoke set carries the related-work splitter stacks (latmem,
# onlinesplit) as single cells on every base scenario — chaos-heavy
# included — plus their challenger differential cells against the
# champion (latmem~mab-daso, onlinesplit~mab-daso on clean+chaos-light),
# and the traffic-plane cells: trace-replay (committed
# tests/traces/edge-burst.json), diurnal-flash-crowd (headline:
# admission + autoscaler + MAB champion under light chaos),
# constrained-edge, single-app and cloud-tier under MC. Since ISSUE-10 the
# base scenarios also include mobility-handoff (mid-interval rack
# handoffs) and battery-constrained (finite batteries, SPEC-curve drain,
# battery-death evictions), and the differential set carries the
# energyfit~mc pairs gating the energy-aware placer's AEC deltas.
if ! ls tests/goldens/*.json >/dev/null 2>&1; then
    echo "no goldens recorded yet — bootstrapping (serial, --update-goldens)"
    ./target/release/splitplace matrix --filter smoke --jobs 1 --update-goldens
fi
./target/release/splitplace matrix --filter smoke --jobs 2

echo "== matrix smoke (sharded integrator vs the serial goldens) =="
# Second parallelism axis: --shards N fans the CPU phase of every interval
# across N threads INSIDE each cell. The order-free accumulator makes the
# sharded walk byte-identical to the serial one, so both runs gate against
# the exact goldens the serial bootstrap recorded — under --jobs 1 and
# --jobs N, per the shard-determinism contract. Any drift fails here.
./target/release/splitplace matrix --filter smoke --jobs 1 --shards 4
./target/release/splitplace matrix --filter smoke --jobs 2 --shards 4

echo "== matrix smoke (paranoid: indexed oracles vs full-scan twins) =="
# The oracle plane runs O(active) index-backed derivations on the hot
# path; --paranoid re-runs every full-pool scan twin each interval and
# reports any scan-vs-index divergence as its own oracle violation. Since
# the sub-step/placement index migration the paranoid sweep also covers
# the phase-1/phase-3 state partitions (via the engine's full-scan
# verify_indices) and the tournament-tree best-fit placer (per-slot
# full-fleet scan twin). The goldens must still match byte-for-byte:
# paranoia only audits, never perturbs.
./target/release/splitplace matrix --filter smoke --jobs 1 --paranoid

echo "== matrix mobility leg (handoffs + battery deaths, paranoid) =="
# The mobility/energy adversary plane (ISSUE-10): the substring filter
# matches every mobility-heavy AND mobility-handoff cell in the smoke set,
# so each policy rides out mid-interval rack handoffs (in-flight transfers
# stretched, rack membership re-homed) with the full-scan oracle twins
# armed — in particular handoff-preserves-progress, whose indexed check
# and paranoid full-pool twin must agree that no completed work is lost
# and no transfer double-charged across a handoff.
./target/release/splitplace matrix --filter mobility --jobs 1 --paranoid

echo "== chaos smoke (paranoid: placement + phase-index twins, heavy) =="
# A best-fit-backed policy under a heavy fault plan with --paranoid: every
# interval re-derives each placement decision with the retired full-fleet
# scan and cross-checks every engine index (transit/blocked partitions
# included) against full-pool recomputations. Any mismatch surfaces as a
# paranoid-divergence violation and fails the run.
./target/release/splitplace chaos --seed 7 --profile heavy --intervals 10 \
    --policy mc --paranoid

# Nightly stanza (uncomment in a scheduled job, not in per-commit CI —
# the full cross product runs all 10 policies × all 20 scenarios × seeds,
# including the 1000/5000/25 000-worker tier cells, the traffic plane's Fig-13/16/18
# regimes (constrained-edge, single-app, cloud-tier), the mobility/energy
# plane (mobility-handoff, battery-constrained) and every differential
# pair — the energyfit~mc AEC pairs included):
# ./target/release/splitplace matrix --filter full --jobs 4 --seeds 2

echo "== engine throughput bench (smoke: all tiers, short horizon) =="
# Smoke-mode perf record AND perf-trajectory gate: every tier, few
# intervals. --gate compares against the committed baseline before
# overwriting it — counters exactly (a drift there is a determinism
# break), wall-clock rates with a wide regression-only band. While the
# committed BENCH_engine.json is still the measured:false placeholder the
# gate skips with a warning; once a toolchain-equipped box records a real
# baseline, a throughput collapse fails CI here. The full ≥50-interval
# measurement is `./target/release/splitplace bench` (or `cargo bench
# --bench engine_throughput`).
./target/release/splitplace bench --tier all --intervals 12 \
    --gate BENCH_engine.json --out BENCH_engine.json

echo "== bench phase breakdown (large tier, informational) =="
# Per-phase wall-ms attribution (decision_ms/network_ms/...) on the
# 1000-worker tier: after the sub-step/placement index migration this is
# where the decision- and network-phase costs are read off. Writes to a
# scratch file — informational only, the committed baseline and the perf
# gate above are untouched.
./target/release/splitplace bench --tier large --intervals 12 \
    --out "$(mktemp -t bench_phases.XXXXXX.json)"

# Lints run after the functional gates so a formatting nit never blocks
# the golden bootstrap above; they still fail the script.
echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (deny warnings) =="
cargo clippy --release -- -D warnings

echo "CI OK"
