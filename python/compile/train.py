"""Build-time training of all split-network variants (runs once, in
`make artifacts`). Hand-rolled Adam — the host image has no optax.

Training uses the pure-jnp forward (`use_pallas=False`): the Pallas
interpret path is numerically identical (validated by pytest) but orders of
magnitude slower to trace inside a training loop. The *exported* inference
HLOs route through the Pallas kernel (see aot.py).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, nets
from .datasets import AppSpec


# ---------------------------------------------------------------------------
# Optimizer (Adam)
# ---------------------------------------------------------------------------

def adam_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return jax.tree_util.tree_map(zeros, params), jax.tree_util.tree_map(zeros, params)


def adam_update(params, grads, m, v, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree_util.tree_map(lambda mm, g: b1 * mm + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda vv, g: b2 * vv + (1 - b2) * g * g, v, grads)
    mhat = jax.tree_util.tree_map(lambda mm: mm / (1 - b1**step), m)
    vhat = jax.tree_util.tree_map(lambda vv: vv / (1 - b2**step), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, m, v


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return jnp.mean(logz - picked)


def accuracy(logits: np.ndarray, labels: np.ndarray) -> float:
    return float(np.mean(np.argmax(logits, axis=1) == labels))


# ---------------------------------------------------------------------------
# Full / compressed nets
# ---------------------------------------------------------------------------

def train_mlp(key, dims, acts, x, y, steps: int, batch: int = 128, lr: float = 1e-3):
    params = nets.init_mlp(key, dims)
    m, v = adam_init(params)

    @jax.jit
    def step_fn(params, m, v, step, xb, yb):
        def loss_fn(p):
            logits = nets.forward(xb, p, acts, use_pallas=False)
            return softmax_xent(logits, yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, m, v = adam_update(params, grads, m, v, step, lr=lr)
        return params, m, v, loss

    n = x.shape[0]
    rng = np.random.default_rng(0)
    for s in range(1, steps + 1):
        idx = rng.integers(0, n, size=batch)
        params, m, v, _ = step_fn(params, m, v, jnp.float32(s), x[idx], y[idx])
    return params


def eval_full(params, acts, x_test, y_test) -> float:
    logits = np.asarray(nets.forward(jnp.asarray(x_test), params, acts, use_pallas=False))
    return accuracy(logits, y_test)


# ---------------------------------------------------------------------------
# Semantic subnets
# ---------------------------------------------------------------------------

def train_semantic(key, spec: AppSpec, x, y, steps: int, batch: int = 128):
    """Train each class-group subnet one-vs-rest: cross-entropy over the
    group's classes plus a trailing "other" class that absorbs out-of-group
    samples. The "other" logit calibrates the cross-group argmax merge (the
    exported fragment emits `logits[:, :-1] - logits[:, -1:]`), while the
    subnets still share no cross-group information — preserving the paper's
    layer > semantic accuracy gap."""
    groups = datasets.class_groups(spec)
    frags = nets.init_semantic_fragments(key, spec)
    rng = np.random.default_rng(1)
    n = x.shape[0]

    for frag, group in zip(frags, groups):
        lo = group[0]
        g = len(group)
        acts = frag.acts

        @jax.jit
        def step_fn(params, m, v, step, xb, yb_local, w):
            def loss_fn(p):
                logits = nets.forward(xb, p, acts, use_pallas=False)
                logz = jax.nn.logsumexp(logits, axis=1)
                picked = jnp.take_along_axis(logits, yb_local[:, None], axis=1)[:, 0]
                return jnp.mean((logz - picked) * w)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, m, v = adam_update(params, grads, m, v, step)
            return params, m, v, loss

        params = frag.params
        m, v = adam_init(params)
        for s in range(1, steps + 1):
            idx = rng.integers(0, n, size=batch)
            xb, yb = x[idx], y[idx]
            in_g = np.isin(yb, group)
            # in-group -> local index; out-of-group -> the "other" class g
            yb_local = np.where(in_g, yb - lo, g).astype(np.int32)
            # down-weight "other" so it doesn't swamp small groups
            w = np.where(in_g, 1.0, 0.5).astype(np.float32)
            params, m, v, _ = step_fn(params, m, v, jnp.float32(s), xb, yb_local, w)
        frag.params = params
    return frags


def eval_semantic(frags: List[nets.Fragment], x_test, y_test) -> float:
    logits = np.asarray(nets.semantic_concat(frags, jnp.asarray(x_test), use_pallas=False))
    return accuracy(logits, y_test)


def magnitude_prune(params, frac: float):
    """BottleNet++-style lossy compression: zero the `frac` smallest-magnitude
    weights per tensor (the paper implements its MC baseline with the
    PyTorch Prune library; this is the same structural operation)."""
    out = []
    for w, b in params:
        wn = np.asarray(w)
        thr = np.quantile(np.abs(wn), frac)
        out.append((jnp.asarray(np.where(np.abs(wn) >= thr, wn, 0.0)), b))
    return out


# ---------------------------------------------------------------------------
# Top-level: train every variant for one app
# ---------------------------------------------------------------------------

def train_app(spec: AppSpec, seed: int = 0, full_steps: int | None = None,
              sem_steps: int | None = None, comp_steps: int | None = None) -> Dict:
    """Returns dict with trained params + measured test accuracies."""
    full_steps = full_steps or spec.train_steps
    sem_steps = sem_steps or spec.train_steps
    comp_steps = comp_steps or max(120, spec.train_steps // 2)

    x_train, y_train, x_test, y_test = datasets.make_dataset(spec, seed)
    key = jax.random.PRNGKey(seed)
    k_full, k_sem, k_comp = jax.random.split(key, 3)

    dims = nets.layer_dims(spec)
    acts = nets.activations_for(dims)
    full_params = train_mlp(k_full, dims, acts, x_train, y_train, steps=full_steps, batch=256)
    acc_full = eval_full(full_params, acts, x_test, y_test)

    layer_frags = nets.layer_fragments(spec, full_params)

    sem_frags = train_semantic(k_sem, spec, x_train, y_train, steps=sem_steps)
    acc_sem = eval_semantic(sem_frags, x_test, y_test)

    cdims = nets.compressed_dims(spec)
    cacts = nets.activations_for(cdims)
    comp_params = train_mlp(k_comp, cdims, cacts, x_train, y_train, steps=comp_steps)
    comp_params = magnitude_prune(comp_params, spec.prune_frac)
    acc_comp = eval_full(comp_params, cacts, x_test, y_test)

    full_frag = nets.Fragment(
        name=f"{spec.name}_full", params=full_params, acts=acts,
        in_dim=spec.dim, out_dim=spec.classes,
    )
    comp_frag = nets.Fragment(
        name=f"{spec.name}_comp", params=comp_params, acts=cacts,
        in_dim=spec.dim, out_dim=spec.classes,
    )

    return {
        "spec": spec,
        "full": full_frag,
        "layer": layer_frags,
        "semantic": sem_frags,
        "compressed": comp_frag,
        "accuracy": {"layer": acc_full, "semantic": acc_sem, "compressed": acc_comp},
        "test": (x_test, y_test),
    }
