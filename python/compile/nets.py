"""Split-network definitions (L2) built on the L1 Pallas fused-dense kernel.

Four variants per app, mirroring the paper's strategy space:

  full        — the unsplit reference MLP (used for the cloud baseline,
                Fig. 18, and as the source of layer fragments)
  layer       — the full net partitioned into sequential layer groups
                (exact: composing the fragments reproduces `full` bit-for-bit)
  semantic    — G parallel subnets, one per class group, each trained only
                on its group (SplitNet-style); prediction = argmax over the
                concatenated group logits
  compressed  — a single small net (BottleNet++-style MC baseline)

Architecture per app (hidden widths scale with difficulty):
  mnist / fashionmnist : 784-256-128-10   (3 dense layers -> 3 layer frags)
  cifar100             : 1024-512-256-100
Semantic subnets use width/g hidden layers and |group| outputs.
Compressed nets use a single 64-wide hidden layer.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .datasets import AppSpec, class_groups
from .kernels import fused_mlp, ref

Params = List[Tuple[jnp.ndarray, jnp.ndarray]]


def hidden_widths(spec: AppSpec) -> List[int]:
    if spec.dim >= 1024:
        return [512, 256]
    return [256, 128]


def layer_dims(spec: AppSpec) -> List[int]:
    return [spec.dim] + hidden_widths(spec) + [spec.classes]


def activations_for(dims: Sequence[int]) -> List[str]:
    """ReLU on hidden layers, linear logits."""
    return ["relu"] * (len(dims) - 2) + ["none"]


def init_mlp(key, dims: Sequence[int]) -> Params:
    """He-init MLP parameters."""
    params = []
    for i in range(len(dims) - 1):
        key, k1 = jax.random.split(key)
        scale = jnp.sqrt(2.0 / dims[i])
        w = jax.random.normal(k1, (dims[i], dims[i + 1]), jnp.float32) * scale
        b = jnp.zeros((dims[i + 1],), jnp.float32)
        params.append((w, b))
    return params


def forward(x: jnp.ndarray, params: Params, acts: Sequence[str], use_pallas: bool = True) -> jnp.ndarray:
    """Forward pass; `use_pallas=True` routes through the L1 kernel so the
    AOT-lowered HLO contains the kernel's tiled program."""
    if use_pallas:
        return fused_mlp.mlp_forward(x, params, acts)
    return ref.mlp_ref(x, params, acts)


# ---------------------------------------------------------------------------
# Fragmentation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Fragment:
    """One deployable split fragment: a contiguous stack of dense layers.

    `subtract_other=True` marks a semantic fragment trained with an extra
    trailing "other" logit (one-vs-rest calibration): the exported output is
    `logits[:, :-1] - logits[:, -1:]`, which keeps cross-group argmax merges
    calibrated while the fragment still never sees other groups' classes.
    """

    name: str
    params: Params
    acts: List[str]
    in_dim: int
    out_dim: int
    subtract_other: bool = False

    def apply(self, x: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
        h = forward(x, self.params, self.acts, use_pallas)
        if self.subtract_other:
            h = h[:, :-1] - h[:, -1:]
        return h

    def param_bytes(self) -> int:
        return sum(int(w.size + b.size) * 4 for w, b in self.params)


def layer_fragments(spec: AppSpec, params: Params) -> List[Fragment]:
    """Partition the full net layer-wise: one fragment per dense layer
    (preliminary / intermediate / final, paper §3.1)."""
    dims = layer_dims(spec)
    acts = activations_for(dims)
    frags = []
    for i, ((w, b), act) in enumerate(zip(params, acts)):
        frags.append(
            Fragment(
                name=f"{spec.name}_layer{i}",
                params=[(w, b)],
                acts=[act],
                in_dim=dims[i],
                out_dim=dims[i + 1],
            )
        )
    return frags


def semantic_subnet_dims(spec: AppSpec, group_size: int) -> List[int]:
    """Subnet layer dims; output has one extra slot for the "other" logit.

    Width is h/(2g): the g parallel subnets together hold ~half the full
    net's capacity, which reproduces the paper's ~4-point layer>semantic
    accuracy gap (Fig. 2 / Table 4)."""
    g = spec.semantic_groups
    hw = [max(12, h // (2 * g)) for h in hidden_widths(spec)]
    return [spec.dim] + hw + [group_size + 1]


def init_semantic_fragments(key, spec: AppSpec) -> List[Fragment]:
    """One parallel subnet per class group. Each subnet sees the full input
    but only emits logits for its own classes (plus the "other" calibration
    logit) — the tree-structured SplitNet layout with no cross-branch
    connections."""
    frags = []
    for gi, group in enumerate(class_groups(spec)):
        key, k = jax.random.split(key)
        dims = semantic_subnet_dims(spec, len(group))
        frags.append(
            Fragment(
                name=f"{spec.name}_sem{gi}",
                params=init_mlp(k, dims),
                acts=activations_for(dims),
                in_dim=spec.dim,
                out_dim=len(group),
                subtract_other=True,
            )
        )
    return frags


def compressed_dims(spec: AppSpec) -> List[int]:
    return [spec.dim, 128, spec.classes]


def semantic_concat(frags: List[Fragment], x: jnp.ndarray, use_pallas: bool = True) -> jnp.ndarray:
    """Concatenate group logits in class order (the broker-side merge the
    paper implements with rsync + torch.cat)."""
    outs = [f.apply(x, use_pallas) for f in frags]
    return jnp.concatenate(outs, axis=1)
