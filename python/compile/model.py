"""L2: the DASO surrogate model f([S_t, P_t, D_t]; theta) -> O_t estimate.

Three programs are AOT-lowered for the rust coordinator (per cluster-size
variant):

  fwd    (params, x[F])              -> y                 scalar QoS estimate
  grad   (params, x[F])              -> (y, dy/dx[F])     for eq. (12):
                                         P_t <- P_t + eta * df/dP
  train  (params, m, v, step, xb, yb)-> (loss, params', m', v')
                                         one AdamW step on MSE (eq. 11)

Feature layout (MUST match rust/src/placement/features.rs exactly):

  [ 0 .. H*4 )        per-worker utilization: cpu, ram, net, disk   in [0,1]
  [ H*4 .. +M*H )     placement matrix P, slot-major (slot m, worker h)
  [ +M*H .. +M*2 )    split decision one-hot per slot: [layer, semantic]
  [ +M*2 .. +M*4 )    per-slot container demands: cpu, ram, net, remaining

  F = H*4 + M*H + M*2 + M*4

The surrogate forward used for the *fwd* artifact routes through the L1
Pallas fused-dense kernel; grad/train use the numerically-identical pure-jnp
reference (AD through the interpret-mode in-place accumulator is not
supported), which pytest validates against the kernel.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from . import nets
from .kernels import fused_mlp, ref

HIDDEN = [512, 256]


@dataclasses.dataclass(frozen=True)
class SurrogateDims:
    """Cluster-size variant of the surrogate."""

    workers: int      # H
    slots: int        # M: max containers considered per interval

    @property
    def state_dim(self) -> int:
        return self.workers * 4

    @property
    def placement_dim(self) -> int:
        return self.slots * self.workers

    @property
    def decision_dim(self) -> int:
        return self.slots * 2

    @property
    def demand_dim(self) -> int:
        return self.slots * 4

    @property
    def feature_dim(self) -> int:
        return self.state_dim + self.placement_dim + self.decision_dim + self.demand_dim

    @property
    def name(self) -> str:
        return f"h{self.workers}_m{self.slots}"

    def layer_dims(self) -> List[int]:
        return [self.feature_dim] + HIDDEN + [1]


# The two variants shipped in artifacts/: the paper's 50-worker testbed and
# a small variant for quickstart/tests.
VARIANTS = [SurrogateDims(workers=50, slots=64), SurrogateDims(workers=10, slots=16)]


def init_params(dims: SurrogateDims, seed: int = 0) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    return nets.init_mlp(jax.random.PRNGKey(seed), dims.layer_dims())


def _acts(dims: SurrogateDims) -> List[str]:
    return nets.activations_for(dims.layer_dims())


def flatten_params(params) -> List[jnp.ndarray]:
    flat = []
    for w, b in params:
        flat += [w, b]
    return flat


def unflatten_params(flat) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    return [(flat[i], flat[i + 1]) for i in range(0, len(flat), 2)]


# ---------------------------------------------------------------------------
# Programs
# ---------------------------------------------------------------------------

def fwd_program(dims: SurrogateDims):
    """fwd(params..., x) -> (y,), Pallas-kernel forward."""
    acts = _acts(dims)

    def fwd(*args):
        x = args[-1][None, :]  # [1, F]
        params = unflatten_params(list(args[:-1]))
        y = fused_mlp.mlp_forward(x, params, acts)
        return (y[0, 0],)

    return fwd


def fwd_batch_program(dims: SurrogateDims, batch: int):
    """Batched scoring: fwd(params..., xb[B,F]) -> (y[B],). Used by the
    coordinator to score many candidate placements in one PJRT call."""
    acts = _acts(dims)

    def fwd(*args):
        xb = args[-1]
        params = unflatten_params(list(args[:-1]))
        y = fused_mlp.mlp_forward(xb, params, acts)
        return (y[:, 0],)

    return fwd


def grad_program(dims: SurrogateDims):
    """grad(params..., x) -> (y, dy/dx). Pure-jnp forward for AD."""
    acts = _acts(dims)

    def f(params, x):
        y = ref.mlp_ref(x[None, :], params, acts)
        return y[0, 0]

    def grad(*args):
        x = args[-1]
        params = unflatten_params(list(args[:-1]))
        y, dx = jax.value_and_grad(f, argnums=1)(params, x)
        return (y, dx)

    return grad


def train_program(dims: SurrogateDims, batch: int, lr: float = 1e-3, wd: float = 1e-4,
                  b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    """One AdamW step on MSE over a [batch, F] minibatch.

    train(params(2L)..., m(2L)..., v(2L)..., step, xb, yb)
      -> (loss, params'(2L)..., m'(2L)..., v'(2L)...)
    """
    acts = _acts(dims)
    nl = len(dims.layer_dims()) - 1  # number of dense layers
    np_flat = 2 * nl

    def train(*args):
        p_flat = list(args[:np_flat])
        m_flat = list(args[np_flat:2 * np_flat])
        v_flat = list(args[2 * np_flat:3 * np_flat])
        step = args[3 * np_flat]
        xb = args[3 * np_flat + 1]
        yb = args[3 * np_flat + 2]
        params = unflatten_params(p_flat)

        def loss_fn(p):
            pred = ref.mlp_ref(xb, p, acts)[:, 0]
            return jnp.mean((pred - yb) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        g_flat = flatten_params(grads)

        new_p, new_m, new_v = [], [], []
        for p, g, m, v in zip(p_flat, g_flat, m_flat, v_flat):
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1**step)
            vhat = v2 / (1 - b2**step)
            # AdamW: decoupled weight decay
            p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
            new_p.append(p2)
            new_m.append(m2)
            new_v.append(v2)
        return tuple([loss] + new_p + new_m + new_v)

    return train


def example_args_fwd(dims: SurrogateDims, params):
    return flatten_params(params) + [jnp.zeros((dims.feature_dim,), jnp.float32)]


def example_args_fwd_batch(dims: SurrogateDims, params, batch: int):
    return flatten_params(params) + [jnp.zeros((batch, dims.feature_dim), jnp.float32)]


def example_args_train(dims: SurrogateDims, params, batch: int):
    flat = flatten_params(params)
    zeros = [jnp.zeros_like(p) for p in flat]
    return (
        flat + zeros + zeros
        + [jnp.float32(1.0),
           jnp.zeros((batch, dims.feature_dim), jnp.float32),
           jnp.zeros((batch,), jnp.float32)]
    )
