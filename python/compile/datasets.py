"""Synthetic image-classification datasets for the three SplitPlace apps.

The paper evaluates on MNIST / FashionMNIST / CIFAR100 (AIoTBench). This
host has no network access, so we substitute three seeded Gaussian-cluster
datasets of increasing difficulty whose *relative* behaviour matches the
paper's apps (DESIGN.md §3):

  easy    ("mnist")        — 10 classes,  dim 784,  well separated
  medium  ("fashionmnist") — 10 classes,  dim 784,  overlapping
  hard    ("cifar100")     — 100 classes, dim 1024, heavily overlapping

Difficulty is controlled by the ratio of within-class noise to between-class
mean separation, tuned so the trained split networks land near the paper's
accuracy ladder (layer ≈ 93% avg > semantic ≈ 89% avg > compressed).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class AppSpec:
    """Static description of one application (task type)."""

    name: str           # paper-facing alias (mnist / fashionmnist / cifar100)
    dim: int            # input dimensionality
    classes: int        # output classes
    noise: float        # within-class noise std
    sep: float          # class-mean separation scale (difficulty knob)
    n_train: int        # training samples
    n_test: int         # held-out samples (exported for the rust runtime)
    semantic_groups: int  # number of semantic split fragments
    train_steps: int    # Adam steps for the full net at artifact-build time
    prune_frac: float   # magnitude-prune fraction for the MC-baseline net


# Tuned (see DESIGN.md §3) so the trained accuracy ladder approximates the
# paper's: mnist ~0.99, fashionmnist ~0.91, cifar100 ~0.65, with the
# semantic variant a few points below layer in each case.
APPS = {
    "mnist": AppSpec("mnist", dim=784, classes=10, noise=0.55, sep=2.8,
                     n_train=6000, n_test=512, semantic_groups=2, train_steps=800,
                     prune_frac=0.80),
    "fashionmnist": AppSpec("fashionmnist", dim=784, classes=10, noise=0.80, sep=2.7,
                            n_train=6000, n_test=512, semantic_groups=2, train_steps=1000,
                            prune_frac=0.70),
    "cifar100": AppSpec("cifar100", dim=1024, classes=100, noise=0.85, sep=3.5,
                        n_train=20000, n_test=512, semantic_groups=4, train_steps=3000,
                        prune_frac=0.50),
}


def make_dataset(spec: AppSpec, seed: int = 0) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Generate (x_train, y_train, x_test, y_test) for an app.

    Class means sit on a unit-norm random frame; samples add isotropic
    Gaussian noise plus a shared low-rank nuisance component (makes the
    problem non-trivially non-linear, so depth actually helps).
    """
    rng = np.random.default_rng(seed ^ hash(spec.name) & 0xFFFF_FFFF)
    means = rng.normal(size=(spec.classes, spec.dim)).astype(np.float32)
    means /= np.linalg.norm(means, axis=1, keepdims=True)

    # Low-rank nuisance directions shared across classes.
    nuis = rng.normal(size=(8, spec.dim)).astype(np.float32)
    nuis /= np.linalg.norm(nuis, axis=1, keepdims=True)

    def sample(n: int, seed2: int):
        r = np.random.default_rng(seed2)
        y = r.integers(0, spec.classes, size=n).astype(np.int32)
        x = spec.sep * means[y] + spec.noise * r.normal(size=(n, spec.dim)).astype(np.float32)
        # nuisance: class-independent structured noise
        coefs = r.normal(size=(n, nuis.shape[0])).astype(np.float32)
        x += 0.25 * coefs @ nuis
        # squash into a zero-centered, bounded [-1, 1] range (zero-centering
        # matters: un-centered inputs stall deep-net training on this data)
        x = np.tanh(0.8 * x)
        return x.astype(np.float32), y

    x_train, y_train = sample(spec.n_train, seed + 1)
    x_test, y_test = sample(spec.n_test, seed + 2)
    return x_train, y_train, x_test, y_test


def class_groups(spec: AppSpec):
    """Contiguous class partition used by the semantic split (paper §3.1:
    tree-structured split over semantically disjoint class groups)."""
    per = spec.classes // spec.semantic_groups
    groups = []
    for g in range(spec.semantic_groups):
        lo = g * per
        hi = spec.classes if g == spec.semantic_groups - 1 else (g + 1) * per
        groups.append(list(range(lo, hi)))
    return groups
