"""AOT entry point: train every network variant, lower every program to HLO
*text*, and write artifacts/ + manifest.json for the rust runtime.

Run via `make artifacts` (build-time only; Python never runs on the request
path). Interchange is HLO text, NOT `.serialize()`: the image's
xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, model, nets, train

EVAL_BATCH = 256       # rows per inference call from the rust runtime
FWD_BATCH = 16         # candidate placements scored per PJRT call
TRAIN_BATCH = 32       # surrogate fine-tune minibatch


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: fragment weights are baked into the HLO as
    # constants; the default printer elides them as `constant({...})`, which
    # parses back as garbage on the rust side.
    return comp.as_hlo_text(True)


def lower_fragment(frag: nets.Fragment, batch: int) -> str:
    """Lower one split fragment to HLO text. Weights are baked in as
    constants; the only runtime input is the activation batch."""

    def f(x):
        return (frag.apply(x, use_pallas=True),)

    spec = jax.ShapeDtypeStruct((batch, frag.in_dim), jnp.float32)
    return to_hlo_text(jax.jit(f).lower(spec))


def write(path: str, text: str) -> int:
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def write_bin_f32(path: str, arrays) -> None:
    with open(path, "wb") as f:
        for a in arrays:
            f.write(np.asarray(a, dtype="<f4").tobytes())


def write_bin_i32(path: str, a) -> None:
    with open(path, "wb") as f:
        f.write(np.asarray(a, dtype="<i4").tobytes())


def frag_entry(frag: nets.Fragment, hlo_name: str) -> dict:
    return {
        "name": frag.name,
        "hlo": hlo_name,
        "in_dim": frag.in_dim,
        "out_dim": frag.out_dim,
        "param_bytes": frag.param_bytes(),
    }


def emit_app(out_dir: str, name: str, seed: int, manifest: dict, log) -> None:
    spec = datasets.APPS[name]
    t0 = time.time()
    result = train.train_app(spec, seed=seed)
    acc = result["accuracy"]
    log(f"[{name}] trained: layer={acc['layer']:.3f} semantic={acc['semantic']:.3f} "
        f"compressed={acc['compressed']:.3f} ({time.time()-t0:.1f}s)")

    entry = {
        "input_dim": spec.dim,
        "classes": spec.classes,
        "semantic_groups": spec.semantic_groups,
        "accuracy": acc,
        "layer": [],
        "semantic": [],
    }

    for frag in result["layer"]:
        hlo_name = f"{frag.name}.hlo.txt"
        write(os.path.join(out_dir, hlo_name), lower_fragment(frag, EVAL_BATCH))
        entry["layer"].append(frag_entry(frag, hlo_name))
    for frag in result["semantic"]:
        hlo_name = f"{frag.name}.hlo.txt"
        write(os.path.join(out_dir, hlo_name), lower_fragment(frag, EVAL_BATCH))
        entry["semantic"].append(frag_entry(frag, hlo_name))
    for kind in ("full", "compressed"):
        frag = result[kind]
        hlo_name = f"{frag.name}.hlo.txt"
        write(os.path.join(out_dir, hlo_name), lower_fragment(frag, EVAL_BATCH))
        entry[kind] = frag_entry(frag, hlo_name)

    x_test, y_test = result["test"]
    entry["data_x"] = f"data_{name}_x.bin"
    entry["data_y"] = f"data_{name}_y.bin"
    entry["data_rows"] = int(x_test.shape[0])
    write_bin_f32(os.path.join(out_dir, entry["data_x"]), [x_test])
    write_bin_i32(os.path.join(out_dir, entry["data_y"]), y_test)

    manifest["apps"][name] = entry
    log(f"[{name}] emitted {3 + spec.semantic_groups + 2} HLO modules")


def emit_surrogate(out_dir: str, dims: model.SurrogateDims, manifest: dict, log) -> None:
    t0 = time.time()
    params = model.init_params(dims, seed=7)
    flat = model.flatten_params(params)

    fwd = jax.jit(model.fwd_program(dims))
    fwd_hlo = to_hlo_text(fwd.lower(*[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat],
                                     jax.ShapeDtypeStruct((dims.feature_dim,), jnp.float32)))

    fwdb = jax.jit(model.fwd_batch_program(dims, FWD_BATCH))
    fwdb_hlo = to_hlo_text(fwdb.lower(
        *[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat],
        jax.ShapeDtypeStruct((FWD_BATCH, dims.feature_dim), jnp.float32)))

    grad = jax.jit(model.grad_program(dims))
    grad_hlo = to_hlo_text(grad.lower(
        *[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat],
        jax.ShapeDtypeStruct((dims.feature_dim,), jnp.float32)))

    tr = jax.jit(model.train_program(dims, TRAIN_BATCH))
    ex = model.example_args_train(dims, params, TRAIN_BATCH)
    tr_hlo = to_hlo_text(tr.lower(*[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in ex]))

    base = f"surrogate_{dims.name}"
    write(os.path.join(out_dir, f"{base}_fwd.hlo.txt"), fwd_hlo)
    write(os.path.join(out_dir, f"{base}_fwd_batch.hlo.txt"), fwdb_hlo)
    write(os.path.join(out_dir, f"{base}_grad.hlo.txt"), grad_hlo)
    write(os.path.join(out_dir, f"{base}_train.hlo.txt"), tr_hlo)
    write_bin_f32(os.path.join(out_dir, f"{base}_init.bin"), flat)

    manifest["surrogates"][dims.name] = {
        "workers": dims.workers,
        "slots": dims.slots,
        "feature_dim": dims.feature_dim,
        "hidden": model.HIDDEN,
        "fwd": f"{base}_fwd.hlo.txt",
        "fwd_batch": f"{base}_fwd_batch.hlo.txt",
        "fwd_batch_size": FWD_BATCH,
        "grad": f"{base}_grad.hlo.txt",
        "train": f"{base}_train.hlo.txt",
        "train_batch": TRAIN_BATCH,
        "init": f"{base}_init.bin",
        "param_shapes": [list(p.shape) for p in flat],
    }
    log(f"[surrogate {dims.name}] F={dims.feature_dim} emitted 4 HLO modules "
        f"({time.time()-t0:.1f}s)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--apps", default="mnist,fashionmnist,cifar100")
    ap.add_argument("--small-only", action="store_true",
                    help="only emit the h10_m16 surrogate (fast CI path)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    log = lambda msg: print(f"[aot] {msg}", flush=True)
    manifest = {
        "version": 1,
        "eval_batch": EVAL_BATCH,
        "apps": {},
        "surrogates": {},
    }

    t0 = time.time()
    for name in args.apps.split(","):
        emit_app(args.out_dir, name.strip(), args.seed, manifest, log)

    variants = model.VARIANTS
    if args.small_only:
        variants = [v for v in variants if v.workers <= 10]
    for dims in variants:
        emit_surrogate(args.out_dir, dims, manifest, log)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    log(f"done in {time.time()-t0:.1f}s -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
