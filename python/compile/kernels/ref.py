"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth implementations used by pytest to validate the
Pallas kernels in `fused_mlp.py` under `interpret=True`, and by the model
layer when a shape is too small to be worth tiling.
"""
from __future__ import annotations

import jax.numpy as jnp


def apply_activation(y: jnp.ndarray, activation: str) -> jnp.ndarray:
    if activation == "none":
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "gelu":
        # tanh approximation of GELU (matches jax.nn.gelu(approximate=True))
        c = jnp.sqrt(2.0 / jnp.pi).astype(y.dtype)
        return 0.5 * y * (1.0 + jnp.tanh(c * (y + 0.044715 * y**3)))
    raise ValueError(f"unknown activation: {activation}")


def dense_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str = "none") -> jnp.ndarray:
    """Reference fused dense layer: activation(x @ w + b).

    Args:
      x: [m, k] input activations.
      w: [k, n] weights.
      b: [n] bias.
      activation: one of "none", "relu", "tanh", "gelu".
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    return apply_activation(y, activation)


def mlp_ref(x: jnp.ndarray, params, activations) -> jnp.ndarray:
    """Reference MLP forward: sequence of dense layers.

    Args:
      x: [m, d0] input.
      params: list of (w_i [d_i, d_{i+1}], b_i [d_{i+1}]).
      activations: list of activation names, same length as params.
    """
    h = x
    for (w, b), act in zip(params, activations):
        h = dense_ref(h, w, b, act)
    return h
