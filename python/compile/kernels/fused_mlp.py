"""L1 Pallas kernel: fused tiled dense layer (matmul + bias + activation).

This is the compute hot-spot of the whole stack: every split-network
fragment and every DASO surrogate layer is a dense layer, so the entire
request path lowers to repeated invocations of this kernel.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the kernel tiles the
`[m,k] @ [k,n]` product into `(bm, bk) x (bk, bn)` VMEM-resident blocks via
`BlockSpec`, accumulates over the k-grid axis in the f32 output block (MXU
accumulation dtype), and fuses the bias-add + activation into the epilogue
of the last k-step so activations never round-trip through HBM between the
matmul and the nonlinearity.

On this image Pallas MUST run with `interpret=True`: real-TPU lowering
emits a Mosaic custom-call that the CPU PJRT plugin cannot execute. The
interpret path produces identical numerics and lowers to plain HLO, which
is what `aot.py` exports for the rust runtime.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default block shapes: multiples of the TPU MXU tile (128x128) and the
# VPU lane width (128). 128^2 f32 = 64 KiB per block; three live blocks
# (x, w, o) plus double-buffering stay well under the ~16 MiB VMEM budget.
DEFAULT_BM = 128
DEFAULT_BK = 128
DEFAULT_BN = 128

# Adaptive caps (§Perf iteration 2): for the shapes this stack actually
# runs (batch ≤ 256, dims ≤ 1024) larger blocks cut the grid-step count —
# the dominant cost under interpret=True and still a VMEM win on TPU
# (fewer HBM round-trips per output tile). Block bytes stay ≤ ~3.5 MiB.
ADAPT_BM = 256
ADAPT_BK = 512
ADAPT_BN = 512


def pick_blocks(m: int, k: int, n: int):
    """Choose block shape for a problem: prefer the biggest block that
    covers the (padded) dim, capped by the adaptive limits."""
    bm = min(ADAPT_BM, _round_up(m, 8))
    bk = min(ADAPT_BK, _round_up(k, 8))
    bn = min(ADAPT_BN, _round_up(n, 8))
    return bm, bk, bn


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str, k_steps: int):
    """Grid = (m/bm, n/bn, k/bk); k is the innermost (sequential) axis.

    o_ref is revisited for every k-step of a given (i, j) tile, acting as
    the f32 accumulator. Bias + activation are fused into the final k-step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...]
        o_ref[...] = ref.apply_activation(acc, activation)


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("activation", "bm", "bk", "bn", "interpret")
)
def fused_dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    activation: str = "relu",
    bm: int = None,
    bk: int = None,
    bn: int = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused activation(x @ w + b) as a tiled Pallas kernel.

    Shapes need not be multiples of the block sizes; inputs are zero-padded
    to the block grid and the result is sliced back. Zero-padding is exact
    for the matmul (extra k contributes 0) and for the epilogue (padded
    rows/cols are discarded before any consumer sees them).

    Args:
      x: [m, k] f32 input.
      w: [k, n] f32 weights.
      b: [n] f32 bias.
      activation: "none" | "relu" | "tanh" | "gelu".
      bm, bk, bn: block shape (defaults match the 128x128 MXU tile).
      interpret: keep True on CPU (see module docstring).

    Returns:
      [m, n] f32 output.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"shape mismatch: x[{m},{k}] @ w[{k2},{n}]"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    # Adaptive defaults, clamped to the (padded) problem so tiny layers
    # don't blow up the padding ratio.
    abm, abk, abn = pick_blocks(m, k, n)
    bm = min(bm, _round_up(m, 8)) if bm else abm
    bk = min(bk, _round_up(k, 8)) if bk else abk
    bn = min(bn, _round_up(n, 8)) if bn else abn

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp = _pad_to(b, 0, bn)

    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)

    out = pl.pallas_call(
        functools.partial(
            _fused_dense_kernel, activation=activation, k_steps=k_steps
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


def _round_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def mlp_forward(
    x: jnp.ndarray,
    params,
    activations,
    bm: int = None,
    bk: int = None,
    bn: int = None,
) -> jnp.ndarray:
    """MLP forward built entirely from the fused Pallas kernel.

    Args mirror `ref.mlp_ref`; this is what the L2 model graphs call so the
    whole network lowers into repeated fused-dense kernels in one HLO module.
    """
    h = x
    for (w, b), act in zip(params, activations):
        h = fused_dense(h, w, b, activation=act, bm=bm, bk=bk, bn=bn)
    return h


def vmem_bytes_per_block(bm: int, bk: int, bn: int, dtype_bytes: int = 4) -> int:
    """Static VMEM footprint estimate for one grid step (used by the perf
    analysis in EXPERIMENTS.md §Perf): x-block + w-block + bias-block +
    out-accumulator, times 2 for double buffering of the streamed inputs."""
    x_blk = bm * bk * dtype_bytes
    w_blk = bk * bn * dtype_bytes
    b_blk = bn * dtype_bytes
    o_blk = bm * bn * 4  # accumulator always f32
    return 2 * (x_blk + w_blk + b_blk) + o_blk


def mxu_utilization_estimate(m: int, k: int, n: int, bm: int, bk: int, bn: int) -> float:
    """Fraction of MXU issue slots doing useful work, from tile alignment:
    padding waste on each axis lowers utilization multiplicatively."""
    def eff(size: int, block: int) -> float:
        padded = _round_up(size, block)
        return size / padded

    return eff(m, bm) * eff(k, bk) * eff(n, bn)
