"""L1 correctness: the Pallas fused-dense kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the stack — every exported HLO
routes its compute through this kernel. Hypothesis sweeps shapes, block
sizes and activations; explicit cases pin the MXU-aligned and degenerate
shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_mlp, ref

ACTIVATIONS = ["none", "relu", "tanh", "gelu"]


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


def _check(m, k, n, act, bm=128, bk=128, bn=128, seed=0, rtol=2e-5, atol=2e-5):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k0, (m, k))
    w = _rand(k1, (k, n), scale=1.0 / np.sqrt(k))
    b = _rand(k2, (n,))
    got = fused_mlp.fused_dense(x, w, b, activation=act, bm=bm, bk=bk, bn=bn)
    want = ref.dense_ref(x, w, b, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Pinned shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ACTIVATIONS)
def test_mxu_aligned(act):
    _check(128, 128, 128, act)


@pytest.mark.parametrize("act", ACTIVATIONS)
def test_multi_block(act):
    _check(256, 384, 256, act)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (1, 784, 10), (3, 5, 7), (257, 129, 100)])
def test_unaligned_shapes(m, k, n):
    _check(m, k, n, "relu")


def test_exported_fragment_shapes():
    """The exact shapes the AOT path exports (batch 256 fragments)."""
    for (m, k, n) in [(256, 784, 256), (256, 256, 128), (256, 128, 10),
                      (256, 1024, 512), (256, 512, 256), (256, 256, 100)]:
        _check(m, k, n, "relu")


def test_zero_input():
    x = jnp.zeros((16, 32))
    w = jnp.zeros((32, 8))
    b = jnp.full((8,), -1.0)
    out = fused_mlp.fused_dense(x, w, b, activation="relu")
    np.testing.assert_array_equal(np.asarray(out), np.zeros((16, 8)))


def test_bias_only():
    x = jnp.zeros((4, 4))
    w = jnp.zeros((4, 6))
    b = jnp.arange(6, dtype=jnp.float32)
    out = fused_mlp.fused_dense(x, w, b, activation="none")
    np.testing.assert_allclose(np.asarray(out), np.tile(np.arange(6, dtype=np.float32), (4, 1)))


def test_small_blocks():
    _check(64, 64, 64, "relu", bm=16, bk=16, bn=16)


def test_rectangular_blocks():
    _check(100, 200, 50, "tanh", bm=32, bk=64, bn=16)


def test_mlp_forward_matches_ref():
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 6)
    params = [
        (_rand(ks[0], (784, 256), 0.03), _rand(ks[1], (256,))),
        (_rand(ks[2], (256, 128), 0.06), _rand(ks[3], (128,))),
        (_rand(ks[4], (128, 10), 0.09), _rand(ks[5], (10,))),
    ]
    acts = ["relu", "relu", "none"]
    x = _rand(jax.random.PRNGKey(4), (32, 784))
    got = fused_mlp.mlp_forward(x, params, acts)
    want = ref.mlp_ref(x, params, acts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 300),
    n=st.integers(1, 150),
    act=st.sampled_from(ACTIVATIONS),
)
def test_hypothesis_shapes(m, k, n, act):
    _check(m, k, n, act, seed=(m * 7 + k * 3 + n) & 0x7FFF)


@settings(max_examples=15, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 64, 128]),
    bk=st.sampled_from([8, 16, 32, 64, 128]),
    bn=st.sampled_from([8, 16, 32, 64, 128]),
)
def test_hypothesis_blocks(bm, bk, bn):
    _check(96, 112, 80, "relu", bm=bm, bk=bk, bn=bn)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(1e-3, 1e3), m=st.integers(1, 64))
def test_hypothesis_scales(scale, m):
    """Numerical robustness across input magnitudes."""
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(11), 3)
    x = _rand(k0, (m, 48)) * scale
    w = _rand(k1, (48, 24)) / np.sqrt(48)
    b = _rand(k2, (24,))
    got = fused_mlp.fused_dense(x, w, b, activation="none")
    want = ref.dense_ref(x, w, b, "none")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4 * scale)


# ---------------------------------------------------------------------------
# Static perf-analysis helpers (used by EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

def test_vmem_budget_default_blocks():
    bytes_per_block = fused_mlp.vmem_bytes_per_block(128, 128, 128)
    assert bytes_per_block < 16 * 1024 * 1024, "default blocks must fit VMEM"


def test_mxu_utilization_bounds():
    u = fused_mlp.mxu_utilization_estimate(256, 784, 256, 128, 128, 128)
    assert 0.0 < u <= 1.0
    # perfectly aligned => 1.0
    assert fused_mlp.mxu_utilization_estimate(128, 128, 128, 128, 128, 128) == 1.0
    # pathological padding => low utilization
    assert fused_mlp.mxu_utilization_estimate(1, 1, 1, 128, 128, 128) < 1e-4
