"""AOT path: HLO text emission + manifest consistency.

Lowers a small fragment and a small surrogate end-to-end (the exact code
path `make artifacts` uses) and sanity-checks the emitted HLO text. If the
full artifacts/ directory already exists, also cross-checks the manifest.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, datasets, model, nets

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrippable():
    def f(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4]" in text


def test_lower_fragment_contains_dot():
    spec = datasets.APPS["mnist"]
    frag = nets.Fragment(
        name="t",
        params=nets.init_mlp(jax.random.PRNGKey(0), [spec.dim, 32, 10]),
        acts=["relu", "none"],
        in_dim=spec.dim,
        out_dim=10,
    )
    text = aot.lower_fragment(frag, batch=8)
    assert "HloModule" in text
    assert "dot(" in text or "dot " in text, "fused-dense matmul must lower to HLO dot"
    assert f"f32[8,{spec.dim}]" in text, "entry parameter must be the activation batch"


def test_surrogate_fwd_lowers():
    dims = model.SurrogateDims(workers=4, slots=4)
    params = model.init_params(dims, seed=0)
    flat = model.flatten_params(params)
    fwd = jax.jit(model.fwd_program(dims))
    lowered = fwd.lower(
        *[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in flat],
        jax.ShapeDtypeStruct((dims.feature_dim,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text


def test_write_bin_f32(tmp_path):
    p = tmp_path / "x.bin"
    a = np.arange(6, dtype=np.float32).reshape(2, 3)
    b = np.array([7.0], dtype=np.float32)
    aot.write_bin_f32(str(p), [a, b])
    raw = np.fromfile(str(p), dtype="<f4")
    np.testing.assert_array_equal(raw, np.array([0, 1, 2, 3, 4, 5, 7], dtype=np.float32))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_apps_present(self, manifest):
        assert set(manifest["apps"]) == {"mnist", "fashionmnist", "cifar100"}

    def test_all_hlo_files_exist(self, manifest):
        for app in manifest["apps"].values():
            for frag in app["layer"] + app["semantic"] + [app["full"], app["compressed"]]:
                path = os.path.join(ARTIFACTS, frag["hlo"])
                assert os.path.exists(path), frag["hlo"]
                assert os.path.getsize(path) > 100

    def test_accuracy_ladder(self, manifest):
        """Paper §2: layer accuracy > semantic accuracy for every app."""
        for name, app in manifest["apps"].items():
            acc = app["accuracy"]
            assert acc["layer"] > acc["semantic"] - 1e-9, name
            assert acc["layer"] > acc["compressed"], name

    def test_fragment_chains(self, manifest):
        for name, app in manifest["apps"].items():
            frags = app["layer"]
            assert frags[0]["in_dim"] == app["input_dim"]
            assert frags[-1]["out_dim"] == app["classes"]
            for a, b in zip(frags, frags[1:]):
                assert a["out_dim"] == b["in_dim"]
            sem_out = sum(f["out_dim"] for f in app["semantic"])
            assert sem_out == app["classes"]

    def test_data_files(self, manifest):
        for name, app in manifest["apps"].items():
            x = np.fromfile(os.path.join(ARTIFACTS, app["data_x"]), dtype="<f4")
            y = np.fromfile(os.path.join(ARTIFACTS, app["data_y"]), dtype="<i4")
            assert x.size == app["data_rows"] * app["input_dim"]
            assert y.size == app["data_rows"]
            assert y.min() >= 0 and y.max() < app["classes"]

    def test_surrogate_entries(self, manifest):
        for name, s in manifest["surrogates"].items():
            f_dim = s["workers"] * 4 + s["slots"] * s["workers"] + s["slots"] * 2 + s["slots"] * 4
            assert s["feature_dim"] == f_dim
            init = np.fromfile(os.path.join(ARTIFACTS, s["init"]), dtype="<f4")
            n_params = sum(int(np.prod(sh)) for sh in s["param_shapes"])
            assert init.size == n_params
            for key in ("fwd", "fwd_batch", "grad", "train"):
                assert os.path.exists(os.path.join(ARTIFACTS, s[key]))
