"""Surrogate-model (L2) correctness: forward/grad/train programs.

Validates the programs that aot.py lowers for the rust DASO module:
  - fwd (Pallas path) matches the pure-jnp forward;
  - grad matches finite differences on the placement segment;
  - one AdamW train step reduces MSE on a fixed batch;
  - feature-layout arithmetic matches the documented layout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

DIMS = model.SurrogateDims(workers=10, slots=16)


@pytest.fixture(scope="module")
def params():
    return model.init_params(DIMS, seed=1)


def test_feature_layout():
    assert DIMS.state_dim == 40
    assert DIMS.placement_dim == 160
    assert DIMS.decision_dim == 32
    assert DIMS.demand_dim == 64
    assert DIMS.feature_dim == 296
    assert DIMS.name == "h10_m16"
    big = model.SurrogateDims(workers=50, slots=64)
    assert big.feature_dim == 50 * 4 + 64 * 50 + 64 * 2 + 64 * 4


def test_fwd_matches_ref(params):
    fwd = model.fwd_program(DIMS)
    x = jax.random.uniform(jax.random.PRNGKey(0), (DIMS.feature_dim,), jnp.float32)
    got = fwd(*model.flatten_params(params)[:], x)[0]
    acts = ["relu", "relu", "none"]
    want = ref.mlp_ref(x[None, :], params, acts)[0, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_fwd_batch_matches_scalar(params):
    fwdb = model.fwd_batch_program(DIMS, 4)
    fwd = model.fwd_program(DIMS)
    xb = jax.random.uniform(jax.random.PRNGKey(1), (4, DIMS.feature_dim), jnp.float32)
    got = fwdb(*model.flatten_params(params), xb)[0]
    want = [fwd(*model.flatten_params(params), xb[i])[0] for i in range(4)]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_grad_value_matches_fwd(params):
    grad = model.grad_program(DIMS)
    x = jax.random.uniform(jax.random.PRNGKey(2), (DIMS.feature_dim,), jnp.float32)
    y, dx = grad(*model.flatten_params(params), x)
    fwd = model.fwd_program(DIMS)
    y2 = fwd(*model.flatten_params(params), x)[0]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=3e-5, atol=3e-5)
    assert dx.shape == (DIMS.feature_dim,)


def test_grad_finite_difference(params):
    """Check d f / d P on a handful of placement coordinates."""
    grad = model.grad_program(DIMS)
    x = jax.random.uniform(jax.random.PRNGKey(3), (DIMS.feature_dim,), jnp.float32)
    y, dx = grad(*model.flatten_params(params), x)
    acts = ["relu", "relu", "none"]

    def f(xv):
        return float(ref.mlp_ref(jnp.asarray(xv)[None, :], params, acts)[0, 0])

    eps = 1e-3
    p_off = DIMS.state_dim
    for idx in [p_off, p_off + 7, p_off + DIMS.placement_dim - 1]:
        xp = np.array(x)
        xp[idx] += eps
        xm = np.array(x)
        xm[idx] -= eps
        fd = (f(xp) - f(xm)) / (2 * eps)
        np.testing.assert_allclose(np.asarray(dx)[idx], fd, rtol=5e-2, atol=5e-3)


def test_train_step_reduces_loss(params):
    tr = model.train_program(DIMS, batch=8)
    flat = model.flatten_params(params)
    zeros = [jnp.zeros_like(p) for p in flat]
    key = jax.random.PRNGKey(4)
    xb = jax.random.uniform(key, (8, DIMS.feature_dim), jnp.float32)
    yb = jax.random.uniform(jax.random.PRNGKey(5), (8,), jnp.float32)

    out = tr(*flat, *zeros, *zeros, jnp.float32(1.0), xb, yb)
    loss0 = float(out[0])
    n = len(flat)
    p1, m1, v1 = list(out[1:1 + n]), list(out[1 + n:1 + 2 * n]), list(out[1 + 2 * n:1 + 3 * n])
    # run a few more steps on the same batch: loss must drop
    loss = loss0
    for step in range(2, 30):
        out = tr(*p1, *m1, *v1, jnp.float32(step), xb, yb)
        loss = float(out[0])
        p1 = list(out[1:1 + n])
        m1 = list(out[1 + n:1 + 2 * n])
        v1 = list(out[1 + 2 * n:1 + 3 * n])
    assert loss < loss0 * 0.5, f"loss {loss0} -> {loss} did not drop"


def test_train_output_arity(params):
    tr = model.train_program(DIMS, batch=4)
    flat = model.flatten_params(params)
    zeros = [jnp.zeros_like(p) for p in flat]
    xb = jnp.zeros((4, DIMS.feature_dim), jnp.float32)
    yb = jnp.zeros((4,), jnp.float32)
    out = tr(*flat, *zeros, *zeros, jnp.float32(1.0), xb, yb)
    assert len(out) == 1 + 3 * len(flat)


def test_param_roundtrip(params):
    flat = model.flatten_params(params)
    back = model.unflatten_params(flat)
    assert len(back) == len(params)
    for (w1, b1), (w2, b2) in zip(params, back):
        assert w1 is w2 and b1 is b2
