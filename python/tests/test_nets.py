"""L2 correctness: split-network semantics.

Key invariants from the paper (§2, §3.1):
  - layer splitting is EXACT: composing the layer fragments reproduces the
    full network output bit-for-bit (pre-trained model divided layer-wise
    "without affecting output semantics");
  - semantic fragments are disjoint parallel subnets whose concatenated
    logits cover the class space in order;
  - fragment metadata (in/out dims, param bytes) is consistent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import datasets, nets


@pytest.fixture(scope="module", params=["mnist", "cifar100"])
def spec(request):
    return datasets.APPS[request.param]


@pytest.fixture(scope="module")
def full_params(spec):
    return nets.init_mlp(jax.random.PRNGKey(0), nets.layer_dims(spec))


def test_layer_fragment_composition_exact(spec, full_params):
    dims = nets.layer_dims(spec)
    acts = nets.activations_for(dims)
    frags = nets.layer_fragments(spec, full_params)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, spec.dim), jnp.float32)
    want = nets.forward(x, full_params, acts, use_pallas=False)
    h = x
    for frag in frags:
        h = frag.apply(h, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(want))


def test_layer_fragments_chain_dims(spec, full_params):
    frags = nets.layer_fragments(spec, full_params)
    dims = nets.layer_dims(spec)
    assert len(frags) == len(dims) - 1
    assert frags[0].in_dim == spec.dim
    assert frags[-1].out_dim == spec.classes
    for a, b in zip(frags, frags[1:]):
        assert a.out_dim == b.in_dim


def test_semantic_covers_classes(spec):
    frags = nets.init_semantic_fragments(jax.random.PRNGKey(2), spec)
    assert len(frags) == spec.semantic_groups
    assert sum(f.out_dim for f in frags) == spec.classes
    groups = datasets.class_groups(spec)
    flat = [c for g in groups for c in g]
    assert flat == list(range(spec.classes)), "groups must tile the class space in order"


def test_semantic_concat_shape(spec):
    frags = nets.init_semantic_fragments(jax.random.PRNGKey(2), spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, spec.dim), jnp.float32)
    out = nets.semantic_concat(frags, x, use_pallas=False)
    assert out.shape == (4, spec.classes)


def test_semantic_fragments_independent(spec):
    """No cross-branch connections: perturbing one subnet's input slice of
    parameters must not change other groups' logits."""
    frags = nets.init_semantic_fragments(jax.random.PRNGKey(2), spec)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, spec.dim), jnp.float32)
    base = np.asarray(nets.semantic_concat(frags, x, use_pallas=False))
    # perturb fragment 0
    w0, b0 = frags[0].params[0]
    frags[0].params[0] = (w0 + 1.0, b0)
    out = np.asarray(nets.semantic_concat(frags, x, use_pallas=False))
    g0 = frags[0].out_dim
    assert not np.allclose(out[:, :g0], base[:, :g0])
    np.testing.assert_array_equal(out[:, g0:], base[:, g0:])


def test_param_bytes(spec, full_params):
    frags = nets.layer_fragments(spec, full_params)
    total = sum(f.param_bytes() for f in frags)
    want = sum(int(w.size + b.size) * 4 for w, b in full_params)
    assert total == want


def test_compressed_smaller_than_full(spec):
    dims = nets.layer_dims(spec)
    cdims = nets.compressed_dims(spec)
    full_sz = sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))
    comp_sz = sum(cdims[i] * cdims[i + 1] + cdims[i + 1] for i in range(len(cdims) - 1))
    assert comp_sz < full_sz / 2


def test_dataset_determinism():
    s = datasets.APPS["mnist"]
    a = datasets.make_dataset(s, seed=5)
    b = datasets.make_dataset(s, seed=5)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_dataset_ranges():
    s = datasets.APPS["fashionmnist"]
    x_train, y_train, x_test, y_test = datasets.make_dataset(s, seed=0)
    assert x_train.shape == (s.n_train, s.dim)
    assert x_test.shape == (s.n_test, s.dim)
    assert x_train.min() >= -1.0 and x_train.max() <= 1.0
    assert y_train.min() >= 0 and y_train.max() < s.classes
